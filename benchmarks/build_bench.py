"""Index-construction throughput: host loop vs single-compile lax.scan, plus
a find-vs-commit phase split across commit backends and commit-grid tiles
(row schemas: docs/BENCHMARKS.md).

Times a cold build (includes compile — the scan backend pays ONE compile for
the whole schedule, the host loop one per batch shape) and a warm rebuild
(same shapes, compile cache hit — the steady-state rebuild cost that matters
for the fault-tolerance / shard-replacement story in distributed.py).

The ``build_phase`` rows replicate the host driver with find_neighbors and
commit_batch timed separately, once per commit backend × commit tile
(DESIGN.md §7) — the commit share of the wall clock is what the fused
commit-merge kernel attacks, and the ``grid_steps`` / ``pad_step_frac``
columns measure the pad-step reclaim of the tiled grid.
Off-TPU the pallas commit runs in interpret mode, so its wall time is a
correctness-path cost record (like kernel_bench's pallas rows), not a TPU
projection; the row pair pins the reference-vs-fused trajectory per release.

  PYTHONPATH=src:. python benchmarks/build_bench.py
  PYTHONPATH=src:. python benchmarks/build_bench.py --quick   # CI-sized
  REPRO_BENCH_QUICK=1 ...                                     # same as --quick
"""
from __future__ import annotations

import argparse
import os
import time


def _build(cls, items, build_backend: str, insert_batch: int,
           clear: bool = False) -> float:
    import jax
    from repro.core import IpNSW

    if clear:  # a genuinely cold build: profiles share shapes, so without
        jax.clear_caches()  # this only the first combination pays compiles
    idx = cls(
        max_degree=16,
        ef_construction=32,
        insert_batch=insert_batch,
        build_backend=build_backend,
    )
    t0 = time.perf_counter()
    idx.build(items)
    g = idx.graph if isinstance(idx, IpNSW) else idx.ip_graph
    jax.block_until_ready(g.adj)
    return time.perf_counter() - t0


def phase_split_rows(
    profile: str,
    quick: bool,
    backends=None,
    tiles=None,
) -> list:
    """Host-driver build with find/commit timed separately, one row per
    (commit backend, commit tile).  Sizes stay small: the pallas commit is
    interpret-mode off-TPU.  ``profile`` is a benchmarks.common.PROFILES
    name (resolved to its underlying norm-distribution shape at a
    phase-split-sized N).  ``backends``/``tiles`` restrict the matrix (the
    bench-smoke test uses both); by default every commit backend runs, the
    reference once (it has no grid — its row carries ``commit_tile=1``, the
    untiled-layout accounting) and the pallas backend once per tile in
    {1, auto}.

    ``pad_step_frac`` (ROADMAP PR-3 follow-on, closed by the tiled grid):
    the fused commit kernel's grid is statically sized for the all-unique
    worst case — ``ceil(E / T)`` steps of ``T`` targets each — so a batch
    whose E proposals collapse onto ``U < E`` distinct targets runs
    ``ceil(E/T) - ceil(U/T)`` pad steps.  The column reports build-wide
    **pad grid steps per proposal slot**, i.e. pads are normalized by the
    T-invariant worst-case slot budget E (the untiled grid), NOT by the
    tiled grid's own step count — so rows with different tiles are directly
    comparable and T=1 reproduces the historical pads/grid fraction
    (~0.81 at the paper schedule).  See docs/BENCHMARKS.md.  It is a
    property of the insertion schedule and the tile (identical for both
    commit backends — only the pallas one actually runs the grid), measured
    from the committed proposal tables during the timed build.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from benchmarks.common import PROFILES
    from repro.core.build import (
        COMMIT_BACKENDS, bootstrap_graph, commit_batch, find_neighbors,
        resolve_commit_tile,
    )
    from repro.core.similarity import Similarity, prepare_items
    from repro.data import mips_dataset

    n, d, batch, md, ef = (600, 24, 64, 8, 16) if quick else (2000, 48, 128, 16, 32)
    p = dict(PROFILES[profile])
    p.pop("n_mult", None)
    raw = jnp.asarray(mips_dataset(n, d, **p))
    prepared = prepare_items(raw, Similarity.INNER_PRODUCT)
    norms = jnp.linalg.norm(prepared, axis=-1)

    auto_tile = resolve_commit_tile("auto", e=batch * md, norms=norms)
    if tiles is None:
        tiles = (1, auto_tile)

    rows = []
    for cb in (backends if backends is not None else COMMIT_BACKENDS):
        cb_tiles = (1,) if cb == "reference" else tuple(dict.fromkeys(tiles))
        for tile in cb_tiles:
            def one_build(measure: bool):
                g = bootstrap_graph(
                    prepared, norms, max_degree=md, insert_batch=batch,
                    reverse_links=True, commit_backend=cb, commit_tile=tile,
                )
                find_s = commit_s = 0.0
                slot_steps = grid_steps = pad_steps = 0
                start = min(batch, n)
                while start < n:
                    stop = min(start + batch, n)
                    bids = jnp.arange(start, stop, dtype=jnp.int32)
                    t0 = time.perf_counter()
                    nbr, sc = find_neighbors(
                        g, prepared[start:stop], max_degree=md, ef=ef,
                        max_steps=2 * ef,
                    )
                    jax.block_until_ready(nbr)
                    t1 = time.perf_counter()
                    g = commit_batch(
                        g, bids, nbr, sc, norms, commit_backend=cb,
                        commit_tile=tile,
                    )
                    jax.block_until_ready(g.adj)
                    t2 = time.perf_counter()
                    find_s += t1 - t0
                    commit_s += t2 - t1
                    if measure:
                        # E proposal slots = the untiled worst-case grid;
                        # live tiled steps cover the distinct valid targets
                        # (compacted to a bucket-row prefix by ops.py).
                        tgt = np.asarray(nbr).reshape(-1)
                        e = tgt.size
                        u = len(np.unique(tgt[tgt >= 0]))
                        slot_steps += e
                        grid_steps += -(-e // tile)
                        pad_steps += -(-e // tile) - (-(-u // tile))
                    start = stop
                return (
                    (find_s, commit_s, slot_steps, grid_steps, pad_steps)
                    if measure else None
                )

            one_build(measure=False)  # compile warmup
            find_s, commit_s, slot_steps, grid_steps, pad_steps = one_build(
                measure=True
            )
            total = find_s + commit_s
            rows.append(dict(
                bench="build_phase",
                profile=profile,
                commit_backend=cb,
                commit_tile=tile,
                n=n,
                dim=d,
                insert_batch=batch,
                find_s=round(find_s, 3),
                commit_s=round(commit_s, 3),
                commit_share=round(commit_s / total, 3) if total else 0.0,
                grid_steps=grid_steps,
                pad_step_frac=(
                    round(pad_steps / slot_steps, 3) if slot_steps else 0.0
                ),
            ))
    return rows


def run() -> None:
    import jax.numpy as jnp
    from benchmarks.common import DIM, QUICK, dataset, emit
    from repro.core import IpNSW, IpNSWPlus

    profiles = ("music_like", "word_like")  # gaussian / lognormal norm shapes
    indexes = {"ipnsw": IpNSW, "ipnsw_plus": IpNSWPlus}
    build_backends = ("host", "scan")
    insert_batch = 256 if QUICK else 512

    rows = []
    for profile in profiles:
        items, _, _ = dataset(profile)
        items = jnp.asarray(items)
        n = items.shape[0]
        for iname, cls in indexes.items():
            for bb in build_backends:
                cold = _build(cls, items, bb, insert_batch, clear=True)
                warm = _build(cls, items, bb, insert_batch)
                rows.append(
                    dict(
                        bench="build",
                        profile=profile,
                        index=iname,
                        build_backend=bb,
                        n=n,
                        dim=DIM,
                        insert_batch=insert_batch,
                        cold_s=round(cold, 3),
                        warm_s=round(warm, 3),
                        items_per_s=int(n / warm),
                    )
                )
    emit(rows, header=True)
    emit(phase_split_rows("word_like", QUICK), header=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (same as REPRO_BENCH_QUICK=1)")
    args = ap.parse_args()
    if args.quick:
        # must land before benchmarks.common is imported: it sizes at import
        os.environ["REPRO_BENCH_QUICK"] = "1"
    run()
