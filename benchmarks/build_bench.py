"""Index-construction throughput: host loop vs single-compile lax.scan.

Times a cold build (includes compile — the scan backend pays ONE compile for
the whole schedule, the host loop one per batch shape) and a warm rebuild
(same shapes, compile cache hit — the steady-state rebuild cost that matters
for the fault-tolerance / shard-replacement story in distributed.py).

  PYTHONPATH=src:. python benchmarks/build_bench.py
  REPRO_BENCH_QUICK=1 ... # CI-sized
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import DIM, N_ITEMS, QUICK, dataset, emit
from repro.core import IpNSW, IpNSWPlus

PROFILES = ("music_like", "word_like")  # gaussian / lognormal norm shapes
INDEXES = {"ipnsw": IpNSW, "ipnsw_plus": IpNSWPlus}
BUILD_BACKENDS = ("host", "scan")
INSERT_BATCH = 256 if QUICK else 512


def _build(cls, items, build_backend: str, clear: bool = False) -> float:
    if clear:  # a genuinely cold build: profiles share shapes, so without
        jax.clear_caches()  # this only the first combination pays compiles
    idx = cls(
        max_degree=16,
        ef_construction=32,
        insert_batch=INSERT_BATCH,
        build_backend=build_backend,
    )
    t0 = time.perf_counter()
    idx.build(items)
    g = idx.graph if isinstance(idx, IpNSW) else idx.ip_graph
    jax.block_until_ready(g.adj)
    return time.perf_counter() - t0


def run() -> None:
    rows = []
    for profile in PROFILES:
        items, _, _ = dataset(profile)
        items = jnp.asarray(items)
        n = items.shape[0]
        for iname, cls in INDEXES.items():
            for bb in BUILD_BACKENDS:
                cold = _build(cls, items, bb, clear=True)
                warm = _build(cls, items, bb)
                rows.append(
                    dict(
                        bench="build",
                        profile=profile,
                        index=iname,
                        build_backend=bb,
                        n=n,
                        dim=DIM,
                        insert_batch=INSERT_BATCH,
                        cold_s=round(cold, 3),
                        warm_s=round(warm, 3),
                        items_per_s=int(n / warm),
                    )
                )
    emit(rows, header=True)


if __name__ == "__main__":
    run()
