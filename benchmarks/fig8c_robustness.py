"""Figure 8c: robustness to norm-distribution transforms.  ImageNet-A/-B
style: add a constant to every item's Euclidean norm without changing
direction, shrinking the tailing factor.  Paper: ip-NSW's performance moves
with TF; ip-NSW+ is nearly invariant."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import (
    QUICK,
    custom_dataset,
    dataset,
    emit,
    ipnsw_index,
    ipnsw_plus_index,
)
from repro.core import recall_at_k
from repro.core.norms import tailing_factor
from repro.data import mips_dataset

EF = 40
SHIFTS = (0.0, 0.18, 0.36)


def run():
    rows = []
    base_items, queries, _ = dataset("image_like")
    scale = float(np.median(np.linalg.norm(base_items, axis=1)))
    for shift in SHIFTS:
        shifted = mips_dataset(
            base_items.shape[0],
            base_items.shape[1],
            profile="uniform_norm",
            seed=2,
            shift=shift * scale,
        )
        tag = f"imagenet_shift{shift}"
        items, q_np, gt = custom_dataset(tag, shifted, queries)
        q = jnp.asarray(q_np)
        tf_ = tailing_factor(np.linalg.norm(items, axis=1))
        b = ipnsw_index(tag, items)
        p = ipnsw_plus_index(tag, items)
        rb = b.search(q, k=10, ef=EF)
        rp = p.search(q, k=10, ef=EF)
        rows.append(dict(
            bench="fig8c", shift=shift, tf=round(tf_, 3),
            ipnsw_recall=round(recall_at_k(np.asarray(rb.ids), gt), 4),
            ipnsw_evals=round(float(np.mean(np.asarray(rb.evals))), 1),
            ipnswp_recall=round(recall_at_k(np.asarray(rp.ids), gt), 4),
            ipnswp_evals=round(float(np.mean(np.asarray(rp.evals))), 1),
        ))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
