"""Kernel micro-bench: exact-MIPS scan (the retrieval_cand hot path) — jnp
backend wall time on CPU + analytic TPU roofline for the Pallas kernel.

The Pallas kernel itself runs in interpret mode on CPU (orders of magnitude
slower than compiled TPU — wall time meaningless), so this bench reports:
  * jnp backend CPU µs/query (real measurement, sanity scaling)
  * the kernel's analytic TPU time bound: N*d*4 bytes / 819 GB/s (item
    streaming, the design's HBM-bound optimum) + MXU time at 197 TFLOP/s
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit
from repro.core import exact_topk

HBM = 819e9
PEAK = 197e12


def run():
    rows = []
    n = 100_000 if QUICK else 1_000_000
    for (b, d) in ((1, 64), (128, 64), (1, 300)):
        items = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(np.random.default_rng(1).normal(size=(b, d)).astype(np.float32))
        vals, ids = exact_topk(q, items, k=10)  # warm
        jax.block_until_ready(ids)
        t0 = time.perf_counter()
        for _ in range(3):
            vals, ids = exact_topk(q, items, k=10)
            jax.block_until_ready(ids)
        dt = (time.perf_counter() - t0) / 3
        flops = 2.0 * b * n * d
        bytes_hbm = n * d * 4.0 + b * d * 4.0
        t_mem = bytes_hbm / HBM
        t_mxu = flops / PEAK
        rows.append(dict(
            bench="kernel_mips_topk", B=b, N=n, d=d,
            cpu_us_per_query=round(dt / b * 1e6, 1),
            tpu_bound_us=round(max(t_mem, t_mxu) * 1e6, 1),
            bound="memory" if t_mem > t_mxu else "compute",
        ))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
