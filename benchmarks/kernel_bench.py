"""Kernel micro-bench: exact-MIPS scan (the retrieval_cand hot path) — jnp
backend wall time on CPU + analytic TPU roofline for the Pallas kernel —
plus the Algorithm-1 walk, reference backend vs the fused beam_step kernel.

The Pallas kernels run in interpret mode on CPU (orders of magnitude slower
than compiled TPU — interpret wall time is recorded for trajectory only), so
this bench reports:
  * jnp/reference backend CPU µs/query (real measurement, sanity scaling)
  * pallas backend interpret-mode wall time (correctness-path cost record)
  * analytic TPU time bounds: N*d*4 bytes / 819 GB/s (item streaming, the
    design's HBM-bound optimum) + MXU time at 197 TFLOP/s; for the walk,
    the per-step fused-kernel bound steps*(M*d*4/HBM) per query
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit
from repro.core import exact_topk
from repro.core.build import COMMIT_BACKENDS, build_graph
from repro.core.search import STEP_BACKENDS, beam_search

HBM = 819e9
PEAK = 197e12


def run():
    rows = []
    n = 100_000 if QUICK else 1_000_000
    for (b, d) in ((1, 64), (128, 64), (1, 300)):
        items = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(np.random.default_rng(1).normal(size=(b, d)).astype(np.float32))
        vals, ids = exact_topk(q, items, k=10)  # warm
        jax.block_until_ready(ids)
        t0 = time.perf_counter()
        for _ in range(3):
            vals, ids = exact_topk(q, items, k=10)
            jax.block_until_ready(ids)
        dt = (time.perf_counter() - t0) / 3
        flops = 2.0 * b * n * d
        bytes_hbm = n * d * 4.0 + b * d * 4.0
        t_mem = bytes_hbm / HBM
        t_mxu = flops / PEAK
        rows.append(dict(
            bench="kernel_mips_topk", backend="jnp", B=b, N=n, d=d,
            cpu_us_per_query=round(dt / b * 1e6, 1),
            tpu_bound_us=round(max(t_mem, t_mxu) * 1e6, 1),
            bound="memory" if t_mem > t_mxu else "compute",
        ))
    rows += walk_step_bench()
    rows += commit_merge_bench()
    emit(rows, header=True)
    return rows


def walk_step_bench():
    """Algorithm-1 walk: reference step_fn vs the fused beam_step kernel.

    Sizes are small because the pallas backend runs in interpret mode on CPU;
    the row pair still pins the reference-vs-fused trajectory per release and
    the analytic bound column gives the compiled-TPU expectation.
    """
    n, d, b, m = (500, 48, 4, 8) if QUICK else (2000, 64, 8, 8)
    pool, steps = 16, 24
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) / np.sqrt(d))
    g = build_graph(items, max_degree=m, ef_construction=16, insert_batch=256)
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    # fused step on TPU: M item rows at the 128-lane padded width the kernel
    # actually streams, plus the adjacency row fetched twice (SMEM + VMEM)
    dp = -(-d // 128) * 128
    t_step = (m * dp * 4.0 + 2 * m * 4.0) / HBM
    rows = []
    for backend in STEP_BACKENDS:
        def run_walk():
            return beam_search(
                g, q, init, pool_size=pool, max_steps=steps, k=10,
                backend=backend,
            )
        r = run_walk()
        jax.block_until_ready(r.ids)
        t0 = time.perf_counter()
        reps = 3 if backend == "reference" else 1
        for _ in range(reps):
            r = run_walk()
            jax.block_until_ready(r.ids)
        dt = (time.perf_counter() - t0) / reps
        rows.append(dict(
            bench="walk_step", backend=backend, B=b, N=n, d=d,
            cpu_us_per_query=round(dt / b * 1e6, 1),
            tpu_bound_us=round(int(r.steps) * t_step * 1e6, 3),
            bound="memory",
        ))
    return rows


def commit_merge_bench():
    """Reverse-link commit: the sort-based reference merge vs the fused
    commit-merge kernel (DESIGN.md §7).

    One row per commit backend over the same [E] proposal table (E = B*M,
    one insertion batch).  The pallas row is interpret-mode wall time on CPU
    (correctness-path cost record); ``tpu_bound_us`` is the analytic
    compiled bound — U touched rows each streaming (M+1) item rows at the
    128-lane padded width, the fused path's only HBM traffic (the reference
    additionally sorts the E*(M+1)-row edge table device-wide twice).
    """
    n, d, b, m = (1000, 48, 32, 8) if QUICK else (20_000, 64, 256, 16)
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d))
    adj = jnp.asarray(rng.integers(-1, n, size=(n, m)).astype(np.int32))
    e = b * m
    targets = jnp.asarray(rng.integers(0, n, size=(e,)).astype(np.int32))
    cands = jnp.asarray(
        np.repeat(rng.integers(0, n, size=(b,)), m).astype(np.int32)
    )
    scores = jnp.asarray(rng.normal(size=(e,)).astype(np.float32))
    u = int(len(np.unique(np.asarray(targets))))
    dp = -(-d // 128) * 128
    t_commit = u * (m + 1) * dp * 4.0 / HBM

    from repro.kernels.commit_merge import commit_merge, commit_merge_ref

    rows = []
    for backend in COMMIT_BACKENDS:
        def run_commit():
            if backend == "pallas":
                return commit_merge(adj, items, targets, cands, scores,
                                    max_cands=b)
            return commit_merge_ref(adj, items, targets, cands, scores)

        jax.block_until_ready(run_commit())  # warm
        reps = 3 if backend == "reference" else 1
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(run_commit())
        dt = (time.perf_counter() - t0) / reps
        rows.append(dict(
            bench="commit_merge", backend=backend, B=b, N=n, d=d,
            cpu_us_per_query=round(dt / b * 1e6, 1),
            tpu_bound_us=round(t_commit * 1e6, 3),
            bound="memory",
        ))
    return rows


if __name__ == "__main__":
    run()
