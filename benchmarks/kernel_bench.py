"""Kernel micro-bench: exact-MIPS scan (the retrieval_cand hot path) — jnp
backend wall time on CPU + analytic TPU roofline for the Pallas kernel —
plus the Algorithm-1 walk, reference backend vs the fused beam_step kernel,
each crossed with the storage axis (f32 items vs the int8 quantized store).

The Pallas kernels run in interpret mode on CPU (orders of magnitude slower
than compiled TPU — interpret wall time is recorded for trajectory only), so
this bench reports:
  * jnp/reference backend CPU µs/query (real measurement, sanity scaling)
  * pallas backend interpret-mode wall time (correctness-path cost record)
  * analytic TPU time bounds: N*d*itemsize bytes / 819 GB/s (item streaming,
    the design's HBM-bound optimum) + MXU time at 197 TFLOP/s; for the walk,
    the per-step fused-kernel bound steps*(M*d*itemsize/HBM) per query
  * ``hbm_bytes_per_query`` — the analytic per-query HBM item-stream bytes.
    The f32-vs-int8 row pairs show the ~4x reduction the quantized store
    buys (int8 streams 1-byte codes + one fp32 scale per row, DESIGN.md §8).

  PYTHONPATH=src:. python benchmarks/kernel_bench.py [--storage f32|int8|both]
"""
import argparse
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit
from repro.core import exact_topk, quantize_items
from repro.core.build import COMMIT_BACKENDS, build_graph
from repro.core.search import STEP_BACKENDS, beam_search
from repro.core.storage import STORAGE_BACKENDS

HBM = 819e9
PEAK = 197e12


def _storages(storage: str):
    return STORAGE_BACKENDS if storage == "both" else (storage,)


def run(storage: str = "both"):
    rows = []
    n = 100_000 if QUICK else 1_000_000
    for (b, d) in ((1, 64), (128, 64), (1, 300)):
        items = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(np.random.default_rng(1).normal(size=(b, d)).astype(np.float32))
        for st in _storages(storage):
            if st == "int8":
                store = quantize_items(items)
                # jnp oracle of the quantized scan (the pallas tile path is
                # covered by the parity tests; einsum is the CPU-fast path).
                from repro.kernels.mips_topk import mips_topk_ref

                scan = jax.jit(functools.partial(mips_topk_ref, k=10))

                def run_scan():
                    return scan(q, store.codes, scales=store.scales)

                # 1-byte codes + one fp32 scale per row
                item_bytes = n * d * 1.0 + n * 4.0
            else:
                def run_scan():
                    return exact_topk(q, items, k=10)

                item_bytes = n * d * 4.0
            vals, ids = run_scan()  # warm
            jax.block_until_ready(ids)
            t0 = time.perf_counter()
            for _ in range(3):
                vals, ids = run_scan()
                jax.block_until_ready(ids)
            dt = (time.perf_counter() - t0) / 3
            flops = 2.0 * b * n * d
            bytes_hbm = item_bytes + b * d * 4.0
            t_mem = bytes_hbm / HBM
            t_mxu = flops / PEAK
            rows.append(dict(
                bench="kernel_mips_topk", backend="jnp", storage=st,
                B=b, N=n, d=d,
                cpu_us_per_query=round(dt / b * 1e6, 1),
                tpu_bound_us=round(max(t_mem, t_mxu) * 1e6, 1),
                bound="memory" if t_mem > t_mxu else "compute",
                hbm_bytes_per_query=int(bytes_hbm / b),
            ))
    rows += walk_step_bench(storage)
    rows += commit_merge_bench()
    emit(rows, header=True)
    return rows


def walk_step_bench(storage: str = "both"):
    """Algorithm-1 walk: reference step_fn vs the fused beam_step kernel,
    on fp32 items and on the int8 quantized store.

    Sizes are small because the pallas backend runs in interpret mode on CPU;
    the row pairs still pin the reference-vs-fused and f32-vs-int8
    trajectories per release, and the analytic bound/bytes columns give the
    compiled-TPU expectation (the int8 rows stream M 1-byte rows + M fp32
    scales per step instead of M fp32 rows — the ~4x HBM cut).
    """
    n, d, b, m = (500, 48, 4, 8) if QUICK else (2000, 64, 8, 8)
    pool, steps = 16, 24
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) / np.sqrt(d))
    g = build_graph(items, max_degree=m, ef_construction=16, insert_batch=256)
    store = quantize_items(g.items) if "int8" in _storages(storage) else None
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    # fused step on TPU: M item rows at the 128-lane padded width the kernel
    # actually streams (1 byte/elem for int8 codes + 4 B/row of scales),
    # plus the adjacency row fetched twice (SMEM + VMEM)
    dp = -(-d // 128) * 128
    step_bytes = {
        "f32": m * dp * 4.0 + 2 * m * 4.0,
        "int8": m * dp * 1.0 + m * 4.0 + 2 * m * 4.0,
    }
    rows = []
    for st in _storages(storage):
        for backend in STEP_BACKENDS:
            def run_walk():
                return beam_search(
                    g, q, init, pool_size=pool, max_steps=steps, k=10,
                    backend=backend, storage=st,
                    store=store if st == "int8" else None,
                )
            r = run_walk()
            jax.block_until_ready(r.ids)
            t0 = time.perf_counter()
            reps = 3 if backend == "reference" else 1
            for _ in range(reps):
                r = run_walk()
                jax.block_until_ready(r.ids)
            dt = (time.perf_counter() - t0) / reps
            walk_bytes = int(r.steps) * step_bytes[st]
            rows.append(dict(
                bench="walk_step", backend=backend, storage=st, B=b, N=n, d=d,
                cpu_us_per_query=round(dt / b * 1e6, 1),
                tpu_bound_us=round(walk_bytes / HBM * 1e6, 3),
                bound="memory",
                hbm_bytes_per_query=int(walk_bytes),
            ))
    return rows


def commit_merge_bench():
    """Reverse-link commit: the sort-based reference merge vs the fused
    commit-merge kernel (DESIGN.md §7).

    One row per commit backend over the same [E] proposal table (E = B*M,
    one insertion batch); the pallas row runs the auto-planned grid tile
    and records it in ``commit_tile`` (the reference has no grid — its row
    carries the untiled accounting, 1).  The pallas row is interpret-mode
    wall time on CPU
    (correctness-path cost record); ``tpu_bound_us`` is the analytic
    compiled bound — U touched rows each streaming (M+1) item rows at the
    128-lane padded width, the fused path's only HBM traffic (the reference
    additionally sorts the E*(M+1)-row edge table device-wide twice).
    The build always runs on fp32 items (DESIGN.md §8), so these rows carry
    storage="f32" and the per-insert byte column for symmetry with the rest
    of the table.
    """
    n, d, b, m = (1000, 48, 32, 8) if QUICK else (20_000, 64, 256, 16)
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d))
    adj = jnp.asarray(rng.integers(-1, n, size=(n, m)).astype(np.int32))
    e = b * m
    targets = jnp.asarray(rng.integers(0, n, size=(e,)).astype(np.int32))
    cands = jnp.asarray(
        np.repeat(rng.integers(0, n, size=(b,)), m).astype(np.int32)
    )
    scores = jnp.asarray(rng.normal(size=(e,)).astype(np.float32))
    u = int(len(np.unique(np.asarray(targets))))
    dp = -(-d // 128) * 128
    commit_bytes = u * (m + 1) * dp * 4.0
    t_commit = commit_bytes / HBM

    from repro.kernels.commit_merge import (
        commit_merge, commit_merge_ref, resolve_commit_tile,
    )

    tile = resolve_commit_tile(
        "auto", e=e, norms=jnp.linalg.norm(items, axis=-1)
    )
    rows = []
    for backend in COMMIT_BACKENDS:
        def run_commit():
            if backend == "pallas":
                return commit_merge(adj, items, targets, cands, scores,
                                    max_cands=b, commit_tile=tile)
            return commit_merge_ref(adj, items, targets, cands, scores)

        jax.block_until_ready(run_commit())  # warm
        reps = 3 if backend == "reference" else 1
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(run_commit())
        dt = (time.perf_counter() - t0) / reps
        rows.append(dict(
            bench="commit_merge", backend=backend, storage="f32",
            commit_tile=tile if backend == "pallas" else 1,
            B=b, N=n, d=d,
            cpu_us_per_query=round(dt / b * 1e6, 1),
            tpu_bound_us=round(t_commit * 1e6, 3),
            bound="memory",
            hbm_bytes_per_query=int(commit_bytes / b),
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--storage", default="both",
                    choices=["f32", "int8", "both"],
                    help="storage backends to bench (both = f32 + int8 rows)")
    args = ap.parse_args()
    run(storage=args.storage)
