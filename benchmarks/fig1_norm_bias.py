"""Figure 1: share of the exact top-10 MIPS result set occupied by each norm
group.  Paper: top-5%-norm items take 87.5-100% across four datasets."""
import numpy as np

from benchmarks.common import PROFILES, dataset, emit
from repro.core.norms import group_occupancy, norm_group_of, top_group_share


def run():
    rows = []
    for name in PROFILES:
        items, queries, gt = dataset(name)
        norms = np.linalg.norm(items, axis=1)
        groups = norm_group_of(norms, 20)
        occ = group_occupancy(gt, groups, 20)
        rows.append(
            dict(
                bench="fig1",
                dataset=name,
                n=items.shape[0],
                top5_share=round(top_group_share(gt, norms, 5.0), 4),
                top10_share=round(occ[:2].sum(), 4),
                top25_share=round(occ[:5].sum(), 4),
            )
        )
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
