"""Beyond-paper extensions (row schemas: docs/BENCHMARKS.md):

1. HNSW-hierarchy ip-NSW (the paper's implementation footnote) vs the flat
   max-norm-entry NSW: does the layered descent buy anything when the entry
   heuristic already exploits the norm bias?
2. Norm-filtered index: operationalize Fig 1 — index only the top-p%-norm
   items; recall bound = ground-truth occupancy of the slice; index size,
   build time and walk length shrink by 1/p.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import QUICK, dataset, emit
from repro.core import HierarchicalIpNSW, NormFilteredIndex, recall_at_k
from repro.core.norms import top_group_share
from benchmarks.common import ipnsw_index, ipnsw_plus_index

EF = 40


def run():
    rows = []
    name = "image_like"
    items, queries, gt = dataset(name)
    q = jnp.asarray(queries)

    flat = ipnsw_index(name, items)
    r = flat.search(q, k=10, ef=EF)
    rows.append(dict(bench="beyond_hnsw", variant="flat+maxnorm-entry",
                     recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                     evals=round(float(np.mean(np.asarray(r.evals))), 1)))
    hier = HierarchicalIpNSW(max_degree=16, ef_construction=32,
                             insert_batch=512).build(jnp.asarray(items))
    r = hier.search(q, k=10, ef=EF)
    rows.append(dict(bench="beyond_hnsw", variant="hierarchical",
                     recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                     evals=round(float(np.mean(np.asarray(r.evals))), 1)))
    emit(rows, header=True)

    rows2 = []
    norms = np.linalg.norm(items, axis=1)
    fracs = (0.1, 0.25) if QUICK else (0.05, 0.1, 0.25, 0.5, 1.0)
    for frac in fracs:
        bound = top_group_share(gt, norms, 100.0 * frac) if frac < 1.0 else 1.0
        nf = NormFilteredIndex(keep_frac=frac, plus=True, max_degree=16,
                               ef_construction=32, insert_batch=512).build(
            jnp.asarray(items))
        rf = nf.search(q, k=10, ef=EF)
        rows2.append(dict(
            bench="beyond_norm_filter", keep_frac=frac,
            recall=round(recall_at_k(np.asarray(rf.ids), gt), 4),
            recall_bound=round(bound, 4),
            evals=round(float(np.mean(np.asarray(rf.evals))), 1),
            index_items=len(nf.global_ids),
        ))
    emit(rows2, header=True)
    return rows + rows2


if __name__ == "__main__":
    run()
