"""Fig-8a at larger cardinality (N=120k, closer to the paper's datasets) —
demonstrates that ip-NSW+'s fixed angular-stage cost amortizes with N, plus
a beyond-paper TUNED variant (k'=5, angular ef=5: half the seed budget).

Not part of benchmarks.run (build time ~tens of minutes on CPU); run as
  PYTHONPATH=src python -m benchmarks.fig8a_large
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import IpNSW, IpNSWPlus, exact_topk, recall_at_k
from repro.data import mips_dataset, mips_queries

N, D, B = 120_000, 64, 200
EFS = (10, 20, 40, 80, 160)


def run():
    items = jnp.asarray(mips_dataset(N, D, profile="uniform_norm", seed=2))
    queries = jnp.asarray(mips_queries(B, D, seed=7))
    _, gt = exact_topk(queries, items, k=10)
    gt = np.asarray(gt)

    base = IpNSW(max_degree=16, ef_construction=32, insert_batch=512).build(
        items, progress=True
    )
    plus = IpNSWPlus(max_degree=16, ef_construction=32, insert_batch=512).build(
        items, progress=True
    )

    rows = []
    for ef in EFS:
        r = base.search(queries, k=10, ef=ef)
        rows.append(dict(bench="fig8a_large", n=N, algo="ipnsw", ef=ef,
                         evals=round(float(np.mean(np.asarray(r.evals))), 1),
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4)))
        r = plus.search(queries, k=10, ef=ef)
        rows.append(dict(bench="fig8a_large", n=N, algo="ipnsw+", ef=ef,
                         evals=round(float(np.mean(np.asarray(r.evals))), 1),
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4)))
        # beyond-paper: halve the angular seed budget
        r = plus.search(queries, k=10, ef=ef, ang_ef=5, k_angular=5)
        rows.append(dict(bench="fig8a_large", n=N, algo="ipnsw+tuned", ef=ef,
                         evals=round(float(np.mean(np.asarray(r.evals))), 1),
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4)))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
