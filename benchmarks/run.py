"""Benchmark harness: one module per paper table/figure.  Prints CSV.

  PYTHONPATH=src python -m benchmarks.run            # full
  REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run   # CI-sized
"""
import sys
import time
import traceback

from benchmarks import (
    beyond_paper,
    build_bench,
    fig1_norm_bias,
    fig2_norm_dist,
    fig3_theorem1,
    fig4_indegree,
    fig5_computation,
    fig7_recall_time,
    fig8a_recall_evals,
    fig8b_topk,
    fig8c_robustness,
    kernel_bench,
    thm2_candidates,
)

MODULES = [
    ("fig1_norm_bias", fig1_norm_bias),
    ("fig2_norm_dist", fig2_norm_dist),
    ("fig3_theorem1", fig3_theorem1),
    ("fig4_indegree", fig4_indegree),
    ("fig5_computation", fig5_computation),
    ("fig7_recall_time", fig7_recall_time),
    ("fig8a_recall_evals", fig8a_recall_evals),
    ("fig8b_topk", fig8b_topk),
    ("fig8c_robustness", fig8c_robustness),
    ("thm2_candidates", thm2_candidates),
    ("kernel_bench", kernel_bench),
    ("build_bench", build_bench),
    ("beyond_paper", beyond_paper),
]


def main() -> None:
    failures = []
    for name, mod in MODULES:
        print(f"# === {name} ===")
        t0 = time.time()
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} took {time.time()-t0:.0f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
