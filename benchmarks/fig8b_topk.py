"""Figure 8b: top-5 and top-20 MIPS — ip-NSW+ should win across k."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import QUICK, dataset, emit, ipnsw_index, ipnsw_plus_index
from repro.core import exact_topk, recall_at_k

EFS = (20, 40) if QUICK else (20, 40, 80, 160)


def run():
    rows = []
    name = "image_like"
    items, queries, _ = dataset(name)
    q = jnp.asarray(queries)
    base = ipnsw_index(name, items)
    plus = ipnsw_plus_index(name, items)
    for k in (5, 20):
        _, gt_k = exact_topk(q, jnp.asarray(items), k=k)
        gt_k = np.asarray(gt_k)
        for ef in EFS:
            r = base.search(q, k=k, ef=max(ef, k))
            rows.append(dict(bench="fig8b", k=k, algo="ipnsw", ef=ef,
                             evals=round(float(np.mean(np.asarray(r.evals))), 1),
                             recall=round(recall_at_k(np.asarray(r.ids), gt_k), 4)))
            r = plus.search(q, k=k, ef=max(ef, k))
            rows.append(dict(bench="fig8b", k=k, algo="ipnsw+", ef=ef,
                             evals=round(float(np.mean(np.asarray(r.evals))), 1),
                             recall=round(recall_at_k(np.asarray(r.ids), gt_k), 4)))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
