"""Shard-routing benchmark: what norm-banded partitioning plus upper-bound
routing buys over the round-robin split (row schema: docs/BENCHMARKS.md,
``bench=shard``).

For each norm profile, four rows on the same catalog and query set:

  partition=roundrobin route=none         — the legacy baseline: every query
                                            visits every shard.
  partition=norm_bands route=none         — banding alone: same exhaustive
                                            merge, proves the partition
                                            itself costs no recall.
  partition=norm_bands route=upper_bound  — the headline row: shards whose
                                            Cauchy-Schwarz bound
                                            ``max_norm_s * ||q||`` cannot
                                            beat the running k-th score are
                                            skipped (provably recall-free).
  ... + storage=tiered                    — the routed run with the hot band
                                            f32 and every cold band int8.

``shards_visited_mean`` / ``skipped_frac`` come from the driver's
``RouteStats``; ``evals_saved_frac`` and ``visited_saved_frac`` are measured
against the round-robin baseline row.  The CI gate
(scripts/check_bench_json.py) enforces the ISSUE-10 acceptance bar on the
lognormal (heavy norm tail) profile: ``skipped_frac > 0``, mean shards
visited reduced by >= 30%, recall@10 within 0.01 of the baseline.

All rows use the single-device reference driver — it DEFINES the routing
semantics (core/distributed.py) and runs identically on any host; the
device path's agreement with it is pinned by tests/test_shard_routing.py.

  PYTHONPATH=src:. python benchmarks/shard_bench.py
  PYTHONPATH=src:. python benchmarks/shard_bench.py --quick       # CI-sized
  REPRO_BENCH_QUICK=1 ...                                         # same
"""
from __future__ import annotations

import argparse
import os


def _recall(ids, gt) -> float:
    import numpy as np

    ids, gt = np.asarray(ids), np.asarray(gt)
    hits = sum(len(set(ids[i][ids[i] >= 0]) & set(gt[i]))
               for i in range(len(gt)))
    return hits / (gt.shape[0] * gt.shape[1])


def shard_rows(
    profile: str = "word_like",
    *,
    quick: bool = True,
    index_kind: str = "ipnsw",
    seed: int = 0,
) -> list:
    """All ``bench=shard`` rows for one norm profile."""
    import numpy as np
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core.distributed import (
        build_sharded, sharded_search_reference,
    )
    from repro.data import mips_dataset, mips_queries

    # d=16 keeps query-item cosines high enough that the k-th score crosses
    # the cold bands' bounds — the regime the lognormal gate measures; the
    # full run uses the larger catalog at the same dimensionality.
    n, d, p = (2000, 16, 8) if quick else (10000, 16, 8)
    n_queries = 32 if quick else 256
    k, ef = common.K, 32
    plus = index_kind == "ipnsw_plus"

    prof = dict(common.PROFILES[profile])
    prof.pop("n_mult", None)
    items = jnp.asarray(mips_dataset(n, d, **prof))
    queries = jnp.asarray(mips_queries(n_queries, d, seed=100 + seed))
    gt = np.argsort(-(np.asarray(queries) @ np.asarray(items).T),
                    axis=1, kind="stable")[:, :k]

    build_kw = dict(
        plus=plus, build_backend="scan", max_degree=16, ef_construction=32,
        insert_batch=64,
    )
    indexes = {
        "roundrobin": build_sharded(items, p, partition="roundrobin",
                                    **build_kw),
        "norm_bands": build_sharded(items, p, partition="norm_bands",
                                    storage="int8", **build_kw),
    }

    base = {
        "bench": "shard",
        "profile": profile,
        "norm_profile": prof["profile"],
        "index": index_kind,
        "n": n,
        "dim": d,
        "n_shards": p,
        "k": k,
        "ef": ef,
    }
    cells = [
        ("roundrobin", "none", "f32"),
        ("norm_bands", "none", "f32"),
        ("norm_bands", "upper_bound", "f32"),
        ("norm_bands", "upper_bound", "tiered"),
    ]
    rows = []
    baseline = None
    for partition, route, storage in cells:
        ids, _, evals, stats = sharded_search_reference(
            indexes[partition], queries, k=k, ef=ef, plus=plus,
            route=route, storage=storage, return_stats=True,
        )
        visited = float(np.asarray(stats.shards_visited).mean())
        skipped = float(np.asarray(stats.bound_skips).mean()) / p
        epq = float(np.asarray(evals).mean())
        row = {
            **base,
            "partition": partition,
            "route": route,
            "storage": storage,
            "shards_visited_mean": round(visited, 3),
            "skipped_frac": round(skipped, 4),
            "evals_per_query": round(epq, 1),
            "recall_at_10": round(_recall(ids, gt), 4),
        }
        if baseline is None:
            baseline = row
        row["visited_saved_frac"] = round(
            1.0 - visited / baseline["shards_visited_mean"], 4)
        row["evals_saved_frac"] = round(
            1.0 - epq / baseline["evals_per_query"], 4)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (same as REPRO_BENCH_QUICK=1)")
    ap.add_argument("--profiles", nargs="*", default=None,
                    help="benchmarks.common.PROFILES names "
                         "(default: music_like word_like)")
    ap.add_argument("--index", default="ipnsw",
                    choices=["ipnsw", "ipnsw_plus"])
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    from benchmarks.common import QUICK, emit

    quick = args.quick or QUICK
    profiles = args.profiles or ["music_like", "word_like"]
    first = True
    for profile in profiles:
        rows = shard_rows(profile, quick=quick, index_kind=args.index)
        emit(rows, header=first)
        first = False


if __name__ == "__main__":
    main()
