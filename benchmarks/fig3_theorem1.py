"""Figure 3a: Theorem-1 probability curve P[qx >= qy | ...](alpha);
Figure 3b: cardinality effect — top-5% occupancy vs dataset subsample size
(uniform sampling keeps the norm-distribution shape, the bias grows with N).
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import QUICK, dataset, emit
from repro.core import exact_topk
from repro.core.norms import theorem1_probability, top_group_share


def run():
    rows_a = []
    for alpha in (1.0, 1.1, 1.35, 2.0, 4.0, 8.0):
        rows_a.append(
            dict(
                bench="fig3a",
                alpha=alpha,
                p_larger_ip=round(theorem1_probability(alpha), 4),
            )
        )
    emit(rows_a, header=True)

    items, queries, _ = dataset("image_like")
    n = items.shape[0]
    rng = np.random.default_rng(0)
    rates = (0.05, 0.2, 1.0) if QUICK else (0.02, 0.1, 0.3, 1.0)
    rows_b = []
    for rate in rates:
        m = int(n * rate)
        sub = items[rng.choice(n, m, replace=False)]
        _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(sub), k=10)
        share = top_group_share(np.asarray(gt), np.linalg.norm(sub, axis=1), 5.0)
        rows_b.append(
            dict(bench="fig3b", rate=rate, n=m, top5_share=round(share, 4))
        )
    emit(rows_b, header=True)
    return rows_a + rows_b


if __name__ == "__main__":
    run()
