"""Figure 7: recall-vs-time, ip-NSW vs ip-NSW+ (+ Simple-LSH and brute-force
context).  Wall time here is CPU (relative ordering only; the
hardware-independent axis is Fig 8a, recall-vs-#evaluations).

``--storage int8`` (the default "both" includes it) adds ``ipnsw[int8]`` /
``ipnsw+[int8]`` rows — the quantized-walk + exact-fp32-rerank path
(DESIGN.md §8) over the SAME cached f32-built indexes, so the recall delta
vs the matching f32 row isolates what int8 storage costs (expected: within
0.01 — the rerank recovers the ordering, see tests/test_storage.py)."""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, dataset, emit, ipnsw_index, ipnsw_plus_index
from repro.core import SimpleLSH, exact_topk, recall_at_k

EFS = (10, 20, 40) if QUICK else (10, 20, 40, 80, 160)


def _timed(fn, *args, repeats=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out[0] if isinstance(out, tuple) else out.ids)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out[0] if isinstance(out, tuple) else out.ids)
    return out, (time.perf_counter() - t0) / repeats


def run(storage: str = "both"):
    rows = []
    name = "image_like"
    items, queries, gt = dataset(name)
    q = jnp.asarray(queries)
    base = ipnsw_index(name, items)
    plus = ipnsw_plus_index(name, items)
    lsh = SimpleLSH(n_bits=96).build(jnp.asarray(items))

    for ef in EFS:
        r, dt = _timed(base.search, q, 10, ef)
        rows.append(dict(bench="fig7", dataset=name, algo="ipnsw", knob=ef,
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                         ms_per_query=round(dt / len(queries) * 1e3, 4)))
        r, dt = _timed(plus.search, q, 10, ef)
        rows.append(dict(bench="fig7", dataset=name, algo="ipnsw+", knob=ef,
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                         ms_per_query=round(dt / len(queries) * 1e3, 4)))

    # Storage trajectory: the int8 quantized walk + exact fp32 rerank vs the
    # matching f32 rows above (same indexes, same queries — the recall delta
    # is pure storage effect).
    if storage in ("int8", "both"):
        for algo, idx in (("ipnsw", base), ("ipnsw+", plus)):
            for ef in EFS:
                r, dt = _timed(idx.search, q, 10, ef, storage="int8")
                rows.append(dict(
                    bench="fig7", dataset=name, algo=f"{algo}[int8]", knob=ef,
                    recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                    ms_per_query=round(dt / len(queries) * 1e3, 4)))

    # Walk-backend trajectory: reference vs fused beam_step kernel on a small
    # query slice (the pallas backend runs in interpret mode on CPU, so the
    # slice is kept tiny; recall must match the reference row bit-for-bit).
    qs, gts = q[:8], gt[:8]
    for backend in ("reference", "pallas"):
        r, dt = _timed(base.search, qs, 10, EFS[0], backend=backend, repeats=1)
        rows.append(dict(bench="fig7", dataset=name, algo=f"ipnsw[{backend}]",
                         knob=EFS[0],
                         recall=round(recall_at_k(np.asarray(r.ids), gts), 4),
                         ms_per_query=round(dt / len(qs) * 1e3, 4)))
    for nc in (100, 400, 1600):
        r, dt = _timed(lsh.search, q, 10, nc)
        rows.append(dict(bench="fig7", dataset=name, algo="simple-lsh", knob=nc,
                         recall=round(recall_at_k(np.asarray(r.ids), gt), 4),
                         ms_per_query=round(dt / len(queries) * 1e3, 4)))
    (vals, ids), dt = _timed(exact_topk, q, jnp.asarray(items), 10)
    rows.append(dict(bench="fig7", dataset=name, algo="bruteforce", knob="",
                     recall=1.0, ms_per_query=round(dt / len(queries) * 1e3, 4)))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--storage", default="both",
                    choices=["f32", "int8", "both"],
                    help="storage rows to emit (f32 = classic rows only; "
                         "int8/both add the quantized-walk trajectory)")
    args = ap.parse_args()
    run(storage=args.storage)
