"""Serving-loop benchmark: p50/p99 latency, QPS, recall@10, batch occupancy
and recompile counts under an open-loop Poisson load through the
continuous-batching loop (launch/serve_loop.py) — the multi-user numbers the
one-shot serve.py CLI cannot produce (row schema: docs/BENCHMARKS.md).

Default (and CI) mode runs in VIRTUAL time: the loop advances an injected
VirtualClock by a fixed analytic LinearServiceModel per dispatch, so every
latency column is a deterministic property of (trace, ladder, model) — the
same rows on every machine, no wall-clock flakiness.  The schedule and
result content are real (every dispatch runs the actual compiled walk and
recall is measured on the returned ids); only the time axis is simulated.
``--wall`` swaps in the WallClock for a measured-latency run on the local
machine (numbers then only comparable to same-machine wall rows).

  PYTHONPATH=src:. python benchmarks/serve_bench.py
  PYTHONPATH=src:. python benchmarks/serve_bench.py --quick      # CI-sized
  REPRO_BENCH_QUICK=1 ...                                        # same
"""
from __future__ import annotations

import argparse
import os


def serve_rows(
    profile: str = "word_like",
    *,
    quick: bool = True,
    index_kind: str = "ipnsw",
    rate_qps: float | None = None,
    n_requests: int | None = None,
    wall: bool = False,
    seed: int = 0,
) -> list:
    """One ``bench=serve`` row per (profile, rate): build the index, run the
    Poisson trace through the loop, reduce the responses.  Self-sized like
    build_bench.phase_split_rows — independent of REPRO_BENCH_QUICK's
    import-time sizing so the bench-smoke test can call it directly."""
    import numpy as np
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import exact_topk, recall_at_k
    from repro.data import mips_dataset, mips_queries
    from repro.launch.serve_loop import (
        BucketLadder,
        LinearServiceModel,
        ServeLoop,
        VirtualClock,
        WallClock,
        poisson_trace,
    )

    n, d = (2000, 24) if quick else (20000, 48)
    n_requests = n_requests if n_requests is not None else (96 if quick else 2000)
    rate_qps = rate_qps if rate_qps is not None else (500.0 if quick else 2000.0)
    ladder = BucketLadder(batches=(8, 32), efs=(16, 32, 64))
    model = LinearServiceModel()
    k = common.K

    p = dict(common.PROFILES[profile])
    p.pop("n_mult", None)
    items = mips_dataset(n, d, **p)
    queries = mips_queries(n_requests, d, seed=100 + seed)
    _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(items), k=k)
    gt = np.asarray(gt)
    maker = common.ipnsw_plus_index if index_kind == "ipnsw_plus" \
        else common.ipnsw_index
    index = maker(f"serve_{profile}_{n}", items)

    trace = poisson_trace(
        queries, rate_qps=rate_qps, seed=seed, ef=64,
        classes=("interactive", "standard", "relaxed"),
    )
    clock = WallClock() if wall else VirtualClock()
    loop = ServeLoop(index, ladder=ladder, clock=clock, k=k,
                     service_model=model)
    stats = loop.run(trace)

    by_rid = sorted(stats.responses, key=lambda r: r.rid)
    recall = recall_at_k(np.stack([r.ids for r in by_rid]), gt)
    s = stats.summary()
    return [{
        "bench": "serve",
        "profile": profile,
        "index": index_kind,
        "clock": "wall" if wall else "virtual",
        "n": n,
        "dim": d,
        "ladder": "/".join(f"{b.batch}x{b.ef}" for b in ladder.buckets()),
        "rate_qps": rate_qps,
        "n_requests": n_requests,
        "served": s["served"],
        "batches": s["batches"],
        "p50_ms": round(s["p50_ms"], 4),
        "p99_ms": round(s["p99_ms"], 4),
        "qps": round(s["qps"], 2),
        "recall_at_10": round(float(recall), 4),
        "occupancy": round(s["occupancy"], 4),
        "deadline_miss_frac": round(s["deadline_miss_frac"], 4),
        "recompiles_warmup": s["recompiles_warmup"],
        "recompiles_steady": s["recompiles_steady"],
    }]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (same as REPRO_BENCH_QUICK=1)")
    ap.add_argument("--profiles", nargs="*", default=None,
                    help="benchmarks.common.PROFILES names "
                         "(default: music_like word_like)")
    ap.add_argument("--index", default="ipnsw",
                    choices=["ipnsw", "ipnsw_plus"])
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate in QPS")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--wall", action="store_true",
                    help="measure real latencies on a WallClock instead of "
                         "the deterministic virtual run")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    from benchmarks.common import QUICK, emit

    quick = args.quick or QUICK
    profiles = args.profiles or ["music_like", "word_like"]
    header = True
    for profile in profiles:
        rows = serve_rows(
            profile, quick=quick, index_kind=args.index,
            rate_qps=args.rate, n_requests=args.requests, wall=args.wall,
        )
        emit(rows, header=header)
        header = False


if __name__ == "__main__":
    main()
