"""Serving-loop benchmark: p50/p99 latency, QPS, recall@10, batch occupancy
and recompile counts under an open-loop Poisson load through the
continuous-batching loop (launch/serve_loop.py) — the multi-user numbers the
one-shot serve.py CLI cannot produce (row schema: docs/BENCHMARKS.md).

Default (and CI) mode runs in VIRTUAL time: the loop advances an injected
VirtualClock by a fixed analytic LinearServiceModel per dispatch, so every
latency column is a deterministic property of (trace, ladder, model) — the
same rows on every machine, no wall-clock flakiness.  The schedule and
result content are real (every dispatch runs the actual compiled walk and
recall is measured on the returned ids); only the time axis is simulated.
``--wall`` swaps in the WallClock for a measured-latency run on the local
machine (numbers then only comparable to same-machine wall rows).

  PYTHONPATH=src:. python benchmarks/serve_bench.py
  PYTHONPATH=src:. python benchmarks/serve_bench.py --quick      # CI-sized
  REPRO_BENCH_QUICK=1 ...                                        # same
"""
from __future__ import annotations

import argparse
import os


def serve_rows(
    profile: str = "word_like",
    *,
    quick: bool = True,
    index_kind: str = "ipnsw",
    rate_qps: float | None = None,
    n_requests: int | None = None,
    wall: bool = False,
    seed: int = 0,
) -> list:
    """One ``bench=serve`` row per (profile, rate): build the index, run the
    Poisson trace through the loop, reduce the responses.  Self-sized like
    build_bench.phase_split_rows — independent of REPRO_BENCH_QUICK's
    import-time sizing so the bench-smoke test can call it directly."""
    import numpy as np
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import exact_topk, recall_at_k
    from repro.data import mips_dataset, mips_queries
    from repro.launch.serve_loop import (
        BucketLadder,
        LinearServiceModel,
        ServeLoop,
        VirtualClock,
        WallClock,
        poisson_trace,
    )

    n, d = (2000, 24) if quick else (20000, 48)
    n_requests = n_requests if n_requests is not None else (96 if quick else 2000)
    rate_qps = rate_qps if rate_qps is not None else (500.0 if quick else 2000.0)
    ladder = BucketLadder(batches=(8, 32), efs=(16, 32, 64))
    model = LinearServiceModel()
    k = common.K

    p = dict(common.PROFILES[profile])
    p.pop("n_mult", None)
    items = mips_dataset(n, d, **p)
    queries = mips_queries(n_requests, d, seed=100 + seed)
    _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(items), k=k)
    gt = np.asarray(gt)
    maker = common.ipnsw_plus_index if index_kind == "ipnsw_plus" \
        else common.ipnsw_index
    index = maker(f"serve_{profile}_{n}", items)

    trace = poisson_trace(
        queries, rate_qps=rate_qps, seed=seed, ef=64,
        classes=("interactive", "standard", "relaxed"),
    )
    clock = WallClock() if wall else VirtualClock()
    loop = ServeLoop(index, ladder=ladder, clock=clock, k=k,
                     service_model=model)
    stats = loop.run(trace)

    by_rid = sorted(stats.responses, key=lambda r: r.rid)
    recall = recall_at_k(np.stack([r.ids for r in by_rid]), gt)
    s = stats.summary()
    return [{
        "bench": "serve",
        "profile": profile,
        "index": index_kind,
        "clock": "wall" if wall else "virtual",
        "n": n,
        "dim": d,
        "ladder": "/".join(f"{b.batch}x{b.ef}" for b in ladder.buckets()),
        "rate_qps": rate_qps,
        "n_requests": n_requests,
        "served": s["served"],
        "batches": s["batches"],
        "p50_ms": round(s["p50_ms"], 4),
        "p99_ms": round(s["p99_ms"], 4),
        "qps": round(s["qps"], 2),
        "recall_at_10": round(float(recall), 4),
        "occupancy": round(s["occupancy"], 4),
        "deadline_miss_frac": round(s["deadline_miss_frac"], 4),
        "recompiles_warmup": s["recompiles_warmup"],
        "recompiles_steady": s["recompiles_steady"],
    }]


def obs_overhead_rows(
    profile: str = "word_like",
    *,
    quick: bool = True,
    repeats: int = 3,
    seed: int = 0,
) -> list:
    """One ``bench=obs_overhead`` row: the SAME seeded Poisson trace served
    three ways — bare (no registry, no trace), metrics-on (registry only),
    and traced (registry + TraceContext) — with the loop's host wall time
    (min over ``repeats``, after a warmup run per mode) as the overhead
    axis.  The virtual-clock p50 is a pure function of (trace, ladder,
    model), so base and traced p50 must be EQUAL — kept as columns because
    their divergence would mean observability changed scheduling, which is
    a bug.  scripts/check_bench_json.py gates metrics_overhead_frac <= 5%
    and recompiles_steady_traced == 0 (row schema: docs/BENCHMARKS.md)."""
    import time as _time

    import numpy as np
    from benchmarks import common
    from repro.data import mips_dataset, mips_queries
    from repro.launch.serve_loop import (
        BucketLadder,
        LinearServiceModel,
        ServeLoop,
        VirtualClock,
        poisson_trace,
    )
    from repro.obs import MetricsRegistry, make_trace_context, top_band_share

    n, d = (2000, 24) if quick else (20000, 48)
    n_requests = 96 if quick else 1000
    ladder = BucketLadder(batches=(8, 32), efs=(16, 32, 64))
    model = LinearServiceModel()

    p = dict(common.PROFILES[profile])
    p.pop("n_mult", None)
    items = mips_dataset(n, d, **p)
    queries = mips_queries(n_requests, d, seed=100 + seed)
    index = common.ipnsw_index(f"serve_{profile}_{n}", items)
    trace = poisson_trace(
        queries, rate_qps=500.0 if quick else 2000.0, seed=seed, ef=64,
        classes=("interactive", "standard", "relaxed"),
    )
    norms = np.linalg.norm(np.asarray(items), axis=1)
    ctx = make_trace_context(norms, np.asarray(index.graph.adj))

    # Modes run INTERLEAVED (base, metrics, traced, base, metrics, ...) with
    # min-of-repeats per mode: machine drift (frequency scaling, page cache)
    # moves whole repeats, not adjacent runs, so sequential per-mode timing
    # would fold that drift into the overhead fraction.  The first sweep is
    # an untimed warmup so compiles never land in a timed repeat (the 5% CI
    # gate needs steady-state numbers, not compile noise).
    reg = MetricsRegistry()
    modes = [(None, None), (MetricsRegistry(), None), (reg, ctx)]
    walls = [[] for _ in modes]
    stats = [None] * len(modes)
    for rep in range(repeats + 1):
        for i, (registry, trace_ctx) in enumerate(modes):
            loop = ServeLoop(index, ladder=ladder, clock=VirtualClock(),
                             k=common.K, service_model=model,
                             registry=registry, trace_ctx=trace_ctx)
            t0 = _time.perf_counter()
            stats[i] = loop.run(trace)
            wall = _time.perf_counter() - t0
            if rep > 0:
                walls[i].append(wall)
    (base_wall, metrics_wall, traced_wall) = (min(w) for w in walls)
    base_stats, traced_stats = stats[0], stats[2]

    band = reg.get("walk_evals_by_band").values
    return [{
        "bench": "obs_overhead",
        "profile": profile,
        "n": n,
        "dim": d,
        "n_requests": n_requests,
        "base_wall_s": round(base_wall, 6),
        "metrics_wall_s": round(metrics_wall, 6),
        "traced_wall_s": round(traced_wall, 6),
        "metrics_overhead_frac": round(metrics_wall / base_wall - 1.0, 4),
        "traced_overhead_frac": round(traced_wall / base_wall - 1.0, 4),
        "p50_ms_base": round(base_stats.percentile_ms(50), 4),
        "p50_ms_traced": round(traced_stats.percentile_ms(50), 4),
        "recompiles_steady_traced": traced_stats.recompiles_steady,
        "top_band_share": round(top_band_share(band), 4),
    }]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (same as REPRO_BENCH_QUICK=1)")
    ap.add_argument("--profiles", nargs="*", default=None,
                    help="benchmarks.common.PROFILES names "
                         "(default: music_like word_like)")
    ap.add_argument("--index", default="ipnsw",
                    choices=["ipnsw", "ipnsw_plus"])
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate in QPS")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--wall", action="store_true",
                    help="measure real latencies on a WallClock instead of "
                         "the deterministic virtual run")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    from benchmarks.common import QUICK, emit

    quick = args.quick or QUICK
    profiles = args.profiles or ["music_like", "word_like"]
    header = True
    for profile in profiles:
        rows = serve_rows(
            profile, quick=quick, index_kind=args.index,
            rate_qps=args.rate, n_requests=args.requests, wall=args.wall,
        )
        emit(rows, header=header)
        header = False
    # Observability overhead contract row (ISSUE 9): always measured on the
    # word_like (lognormal) profile so top_band_share doubles as a live
    # norm-bias check; plain ipnsw — the overhead question is per-walk, not
    # per-index-kind.
    emit(obs_overhead_rows("word_like", quick=quick), header=True)


if __name__ == "__main__":
    main()
