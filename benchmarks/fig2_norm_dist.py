"""Figure 2: norm distributions — percentiles and tailing factor
(TF = 95th percentile / median; paper §5)."""
import numpy as np

from benchmarks.common import PROFILES, dataset, emit
from repro.core.norms import tailing_factor


def run():
    rows = []
    for name in PROFILES:
        items, _, _ = dataset(name)
        norms = np.linalg.norm(items, axis=1)
        norms = norms / norms.max()
        rows.append(
            dict(
                bench="fig2",
                dataset=name,
                tf=round(tailing_factor(norms), 3),
                p50=round(float(np.percentile(norms, 50)), 3),
                p95=round(float(np.percentile(norms, 95)), 3),
                p99=round(float(np.percentile(norms, 99)), 3),
            )
        )
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
