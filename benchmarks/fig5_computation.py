"""Figure 5: share of similarity evaluations spent on each norm group during
ip-NSW search.  Paper: 80.7-100% of inner products hit top-5%-norm items —
more concentrated than the in-degree distribution (Fig 4)."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import PROFILES, dataset, emit, ipnsw_index
from repro.core.norms import group_occupancy, norm_group_of


def run():
    rows = []
    for name in PROFILES:
        items, queries, _ = dataset(name)
        idx = ipnsw_index(name, items)
        res = idx.search(jnp.asarray(queries), k=10, ef=64)
        visited = np.asarray(res.visited)
        norms = np.linalg.norm(items, axis=1)
        groups = norm_group_of(norms, 20)
        occ = group_occupancy(visited, groups, 20)
        rows.append(
            dict(
                bench="fig5",
                dataset=name,
                top5_compute_share=round(float(occ[0]), 4),
                top25_compute_share=round(float(occ[:5].sum()), 4),
                evals_per_query=round(float(np.mean(np.asarray(res.evals))), 1),
            )
        )
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
