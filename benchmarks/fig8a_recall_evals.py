"""Figure 8a: recall vs #similarity-evaluations — the hardware-independent
comparison.  One evaluation = one angular-or-inner-product computation
(paper's counting).  The paper's claim: ip-NSW+ needs fewer evaluations for
the same recall."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import PROFILES, QUICK, dataset, emit, ipnsw_index, ipnsw_plus_index
from repro.core import recall_at_k

EFS = (10, 20, 40) if QUICK else (10, 20, 40, 80, 160, 320)


def run():
    rows = []
    datasets = list(PROFILES) if not QUICK else ["image_like"]
    for name in datasets:
        items, queries, gt = dataset(name)
        q = jnp.asarray(queries)
        base = ipnsw_index(name, items)
        plus = ipnsw_plus_index(name, items)
        for ef in EFS:
            r = base.search(q, k=10, ef=ef)
            rows.append(dict(bench="fig8a", dataset=name, algo="ipnsw", ef=ef,
                             evals=round(float(np.mean(np.asarray(r.evals))), 1),
                             recall=round(recall_at_k(np.asarray(r.ids), gt), 4)))
            r = plus.search(q, k=10, ef=ef)
            rows.append(dict(bench="fig8a", dataset=name, algo="ipnsw+", ef=ef,
                             evals=round(float(np.mean(np.asarray(r.evals))), 1),
                             recall=round(recall_at_k(np.asarray(r.ids), gt), 4)))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
