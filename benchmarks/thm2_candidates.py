"""§4.1 experiment behind Theorem 2: aggregate the ground-truth top-10 MIPS
neighbors of the query's ground-truth top-10 ANGULAR neighbors -> candidate
set of 100; its top-10 recall was 82.67% (Yahoo!Music) / 97.22% (ImageNet).
Contrast: MIPS-of-MIPS candidates gave only 67.21% on ImageNet."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import dataset, emit
from repro.core import exact_topk, recall_at_k
from repro.core.similarity import normalize


def _neighbors_excl_self(it, sources, k):
    """Top-k neighbors of dataset rows ``sources`` EXCLUDING the item itself
    (a dataset item's own inner/angular similarity is trivially maximal)."""
    _, nbr = exact_topk(it[jnp.asarray(sources)], it, k=k + 1)
    nbr = np.asarray(nbr)
    out = np.empty((len(sources), k), np.int32)
    for i, s in enumerate(sources):
        row = nbr[i][nbr[i] != s]
        out[i] = row[:k]
    return out


def run():
    rows = []
    for name in ("music_like", "image_like"):
        items, queries, gt = dataset(name)
        it = jnp.asarray(items)
        q = jnp.asarray(queries)
        # ground-truth top-10 angular neighbors of each query
        _, ang = exact_topk(q, normalize(it), k=10)
        # ground-truth top-10 MIPS neighbors of EVERY angular neighbor
        uniq, inv = np.unique(np.asarray(ang).reshape(-1), return_inverse=True)
        nbr_of = _neighbors_excl_self(it, uniq, 10)
        cand_ang = nbr_of[inv].reshape(len(queries), -1)  # [B,100]
        rec_ang = recall_at_k(cand_ang, gt)

        # contrast: MIPS neighbors of the query's MIPS neighbors
        uniq2, inv2 = np.unique(gt.reshape(-1), return_inverse=True)
        nbr2 = _neighbors_excl_self(it, uniq2, 10)
        cand_mips = nbr2[inv2].reshape(len(queries), -1)
        rec_mips = recall_at_k(cand_mips, gt)

        rows.append(dict(bench="thm2", dataset=name,
                         recall_mips_of_angular=round(rec_ang, 4),
                         recall_mips_of_mips=round(rec_mips, 4)))
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
