"""Figure 4: average in-degree per norm group in the ip-NSW graph.
Paper: top-5%-norm items reach 3.2-19.8x the dataset-average in-degree."""
import numpy as np

from benchmarks.common import PROFILES, dataset, emit, ipnsw_index
from repro.core.graph import in_degrees
from repro.core.norms import in_degree_by_group, norm_group_of


def run():
    rows = []
    for name in PROFILES:
        items, _, _ = dataset(name)
        idx = ipnsw_index(name, items)
        ind = in_degrees(idx.graph)
        norms = np.linalg.norm(items, axis=1)
        groups = norm_group_of(norms, 20)
        by_group = in_degree_by_group(ind, groups, 20)
        avg = ind.mean()
        rows.append(
            dict(
                bench="fig4",
                dataset=name,
                avg_indegree=round(float(avg), 2),
                top5_indegree=round(float(by_group[0]), 2),
                top5_over_avg=round(float(by_group[0] / max(avg, 1e-9)), 2),
                bottom50_over_avg=round(float(by_group[10:].mean() / max(avg, 1e-9)), 3),
            )
        )
    emit(rows, header=True)
    return rows


if __name__ == "__main__":
    run()
