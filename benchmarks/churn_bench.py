"""Churn benchmark: recall, health and scheduling behavior of a
``core.mutation.MutableIndex`` under streaming upserts / tombstone deletes /
adversarial hub kills, replayed through the continuous-batching serving loop
(row schema: docs/BENCHMARKS.md, ``bench=churn``).

Three row kinds:

  kind=turnover      — sweep the churn fraction (share of the catalog both
                       deleted and re-upserted while queries stream).  After
                       the trace, repair runs to zero relink debt and the
                       mutated index's recall@10 (against exact MIPS over
                       the CURRENT live catalog) is compared with a fresh
                       rebuild of that same catalog — ``recall_delta`` is
                       the price of mutating in place, the number the CI
                       gate bounds (scripts/check_bench_json.py: >-0.02,
                       and ``rejected`` must be 0).
  kind=relink_sweep  — fixed heavy churn, sweep the per-pass repair budget
                       from 0 to "everything": shows recall and dead-edge
                       fraction as a function of how much repair work the
                       operator buys.
  kind=hub_kill      — tombstone the highest-in-degree live nodes (the §4
                       large-norm routing hubs — the adversarial delete for
                       this graph family), then measure the recovery curve:
                       recall after the kill and after each incremental
                       relink slice.

All rows run in virtual time with the deterministic service model, so they
are a pure function of the seeds — same numbers on every machine.

  PYTHONPATH=src:. python benchmarks/churn_bench.py
  PYTHONPATH=src:. python benchmarks/churn_bench.py --quick      # CI-sized
  REPRO_BENCH_QUICK=1 ...                                        # same
"""
from __future__ import annotations

import argparse
import os


def _exact_live_topk(queries, items, live, k):
    """Ground truth over the mutated catalog: exact top-k restricted to
    live slots (slot-id space)."""
    import numpy as np

    scores = np.asarray(queries, np.float32) @ np.asarray(items, np.float32).T
    scores = np.where(np.asarray(live, bool)[None, : items.shape[0]],
                      scores, -np.inf)
    return np.argsort(-scores, axis=1, kind="stable")[:, :k]


def _recall(ids, gt) -> float:
    import numpy as np

    ids, gt = np.asarray(ids), np.asarray(gt)
    hits = sum(len(set(ids[i][ids[i] >= 0]) & set(gt[i]))
               for i in range(len(gt)))
    return hits / (gt.shape[0] * gt.shape[1])


def _mutable(index_kind: str, items, *, capacity):
    import jax.numpy as jnp
    from repro.core import IpNSW, IpNSWPlus, MutableIndex

    # No common.py build cache: every scenario mutates its own copy.
    cls = IpNSWPlus if index_kind == "ipnsw_plus" else IpNSW
    idx = cls(max_degree=16, ef_construction=32,
              insert_batch=512).build(jnp.asarray(items))
    return MutableIndex(idx, capacity=capacity, mutation_batch=32)


def _rebuild_floor(index_kind: str, m, queries, k) -> float:
    """Fresh-build recall floor: compact the live catalog, rebuild from
    scratch, measure against exact top-k of the compacted set."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import IpNSW, IpNSWPlus

    live = m.live_ids()
    compact = np.asarray(m.graph.items)[live]
    cls = IpNSWPlus if index_kind == "ipnsw_plus" else IpNSW
    fresh = cls(max_degree=16, ef_construction=32,
                insert_batch=512).build(jnp.asarray(compact))
    r = fresh.search(jnp.asarray(queries), k=k, ef=64)
    gt = np.argsort(-(np.asarray(queries) @ compact.T), axis=1,
                    kind="stable")[:, :k]
    return _recall(np.asarray(r.ids), gt)


def churn_rows(
    profile: str = "word_like",
    *,
    quick: bool = True,
    index_kind: str = "ipnsw",
    seed: int = 0,
) -> list:
    """All ``bench=churn`` rows for one norm profile."""
    import numpy as np
    import jax.numpy as jnp
    from benchmarks import common
    from repro.core import ChurnTrace
    from repro.data import mips_dataset, mips_queries
    from repro.launch.serve_loop import (
        BucketLadder,
        LinearServiceModel,
        ServeLoop,
        VirtualClock,
        poisson_trace,
    )

    n, d = (1500, 24) if quick else (12000, 48)
    n_requests = 64 if quick else 512
    k = common.K
    ladder = BucketLadder(batches=(8, 32), efs=(16, 32, 64))

    p = dict(common.PROFILES[profile])
    p.pop("n_mult", None)
    data_profile = p["profile"]
    items = mips_dataset(n, d, **p)
    queries = mips_queries(n_requests, d, seed=100 + seed)

    rows = []
    base = {
        "bench": "churn",
        "profile": profile,
        "index": index_kind,
        "n": n,
        "dim": d,
        "n_requests": n_requests,
    }

    def serve_with_churn(m, churn):
        trace = poisson_trace(
            queries, rate_qps=500.0, seed=seed, ef=64,
            classes=("standard", "relaxed"),
        )
        loop = ServeLoop(m, ladder=ladder, clock=VirtualClock(), k=k,
                         service_model=LinearServiceModel())
        return loop.run(trace, churn=churn)

    def post_recall(m):
        gt = _exact_live_topk(queries, np.asarray(m.graph.items),
                              m._live_host, k)
        r = m.search(jnp.asarray(queries), k=k, ef=64)
        return _recall(np.asarray(r.ids), gt)

    # -- kind=turnover: churn fraction sweep, full repair, rebuild floor ----
    turnovers = (0.1, 0.25) if quick else (0.1, 0.25, 0.5)
    for turnover in turnovers:
        m = _mutable(index_kind, items, capacity=int(n * 1.5))
        churn = ChurnTrace.generate(
            n_items=n, dim=d, duration_s=max(n_requests / 500.0, 0.05),
            turnover=turnover, batch=32, seed=seed + 1,
            profile=data_profile,
        )
        stats = serve_with_churn(m, churn)
        while m.relink_debt():
            m.relink(256)
        rec_post = post_recall(m)
        rec_floor = _rebuild_floor(index_kind, m, queries, k)
        h = m.health()
        s = stats.summary()
        rows.append({
            **base, "kind": "turnover", "turnover": turnover,
            "mutation_events": s["mutation_events"],
            "rejected": s["rejected"],
            "recompiles_steady": s["recompiles_steady"],
            "recall_at_10": round(rec_post, 4),
            "recall_floor": round(rec_floor, 4),
            "recall_delta": round(rec_post - rec_floor, 4),
            "live_fraction": round(h["live_fraction"], 4),
            "dead_edge_frac": round(h["dead_edge_frac"], 4),
            "relink_debt": int(h["relink_debt"]),
        })

    # -- kind=relink_sweep: what a repair budget buys after a mass delete ---
    # A delete+reinsert trace reuses tombstones immediately, so dead edges
    # never accumulate; the scenario that actually stresses repair is a net
    # SHRINK — delete 30% of the catalog outright and leave the tombstones
    # in place, then sweep how much relink work the operator buys.
    budgets = (0, 32, 10**9) if quick else (0, 64, 256, 10**9)
    rng = np.random.default_rng(seed + 2)
    kill = rng.choice(n, size=int(n * 0.3), replace=False)
    for budget in budgets:
        m = _mutable(index_kind, items, capacity=int(n * 1.5))
        m.delete(kill)
        if budget:
            repaired = m.relink(budget)
            while budget >= 10**9 and m.relink_debt():
                repaired += m.relink(256)
        else:
            repaired = 0
        h = m.health()
        rows.append({
            **base, "kind": "relink_sweep", "turnover": 0.3,
            "relink_budget": min(budget, 10**9),
            "relinked": repaired,
            "recall_at_10": round(post_recall(m), 4),
            "dead_edge_frac": round(h["dead_edge_frac"], 4),
            "relink_debt": int(h["relink_debt"]),
        })

    # -- kind=hub_kill: adversarial delete + recovery curve -----------------
    m = _mutable(index_kind, items, capacity=int(n * 1.5))
    n_kill = max(n // 100, 8)
    m.kill_hubs(n_kill)
    slices = 3 if quick else 5
    slice_budget = max(m.relink_debt() // slices, 1)
    step = 0
    while True:
        h = m.health()
        rows.append({
            **base, "kind": "hub_kill", "killed": n_kill,
            "relink_step": step,
            "recall_at_10": round(post_recall(m), 4),
            "dead_edge_frac": round(h["dead_edge_frac"], 4),
            "relink_debt": int(h["relink_debt"]),
        })
        if not m.relink_debt():
            break
        m.relink(slice_budget)
        step += 1
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (same as REPRO_BENCH_QUICK=1)")
    ap.add_argument("--profiles", nargs="*", default=None,
                    help="benchmarks.common.PROFILES names "
                         "(default: music_like word_like)")
    ap.add_argument("--index", default="ipnsw",
                    choices=["ipnsw", "ipnsw_plus"])
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    from benchmarks.common import QUICK, emit

    quick = args.quick or QUICK
    profiles = args.profiles or ["music_like", "word_like"]
    seen_kinds = set()
    for profile in profiles:
        rows = churn_rows(profile, quick=quick, index_kind=args.index)
        # Row schemas differ per kind — print each kind as its own CSV block
        # (the JSON mirror is schema-free either way).
        for kind in ("turnover", "relink_sweep", "hub_kill"):
            block = [r for r in rows if r["kind"] == kind]
            emit(block, header=kind not in seen_kinds)
            seen_kinds.add(kind)


if __name__ == "__main__":
    main()
