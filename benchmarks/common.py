"""Shared benchmark substrate: synthetic datasets with paper-like norm
profiles + cached index builds (several figures reuse the same indexes).

Sizes: full mode targets the paper's qualitative regime on CPU in minutes;
REPRO_BENCH_QUICK=1 shrinks everything for CI.

REPRO_BENCH_JSON=<path> mirrors every row ``emit`` prints into a JSON file
(rewritten after each emit, so a partial run still leaves valid JSON) — CI
uploads these as workflow artifacts so the perf trajectory is inspectable
per PR without scraping the log.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import IpNSW, IpNSWPlus, exact_topk
from repro.data import mips_dataset, mips_queries

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

N_ITEMS = 4_000 if QUICK else 40_000
N_QUERIES = 50 if QUICK else 500
DIM = 48 if QUICK else 64
K = 10

# dataset profiles standing in for the paper's four datasets (Figure 2):
#   music_like  — tight norms near the max (Yahoo!Music / Tiny5M shape)
#   word_like   — heavy-tailed lognormal (WordVector shape)
#   image_like  — heavy-tailed, higher TF (ImageNet shape)
#   tiny_like   — tight norms, larger N (Tiny5M cardinality effect)
PROFILES = {
    "music_like": dict(profile="gaussian", seed=0),
    "word_like": dict(profile="lognormal", seed=1),
    "image_like": dict(profile="uniform_norm", seed=2),
    "tiny_like": dict(profile="gaussian", seed=3, n_mult=2),
}

_cache: dict = {}


def dataset(name: str):
    key = ("data", name)
    if key not in _cache:
        p = dict(PROFILES[name])
        n = N_ITEMS * p.pop("n_mult", 1)
        items = mips_dataset(n, DIM, **p)
        queries = mips_queries(N_QUERIES, DIM, seed=100 + hash(name) % 1000)
        _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(items), k=K)
        _cache[key] = (items, queries, np.asarray(gt))
    return _cache[key]


def custom_dataset(tag: str, items: np.ndarray, queries: np.ndarray):
    key = ("data", tag)
    if key not in _cache:
        _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(items), k=K)
        _cache[key] = (items, queries, np.asarray(gt))
    return _cache[key]


def ipnsw_index(tag: str, items: np.ndarray, **kw) -> IpNSW:
    key = ("ipnsw", tag)
    if key not in _cache:
        params = dict(max_degree=16, ef_construction=32, insert_batch=512)
        params.update(kw)
        t0 = time.time()
        _cache[key] = IpNSW(**params).build(jnp.asarray(items))
        print(f"#   built ip-NSW[{tag}] n={items.shape[0]} in {time.time()-t0:.0f}s")
    return _cache[key]


def ipnsw_plus_index(tag: str, items: np.ndarray, **kw) -> IpNSWPlus:
    key = ("ipnsw+", tag)
    if key not in _cache:
        params = dict(max_degree=16, ef_construction=32, insert_batch=512)
        params.update(kw)
        t0 = time.time()
        _cache[key] = IpNSWPlus(**params).build(jnp.asarray(items))
        print(f"#   built ip-NSW+[{tag}] n={items.shape[0]} in {time.time()-t0:.0f}s")
    return _cache[key]


_json_rows: list = []
_provenance_cache: dict = {}


def provenance() -> dict:
    """Environment provenance stamped onto every bench row so BENCH_*.json
    trajectories are attributable across jax upgrades, commits and machines:
    ``jax_version``, ``git_sha`` (short HEAD, "unknown" outside a checkout)
    and ``device`` (the jax backend the numbers ran on).  Cached — computed
    once per process."""
    if not _provenance_cache:
        import subprocess

        import jax

        try:
            sha = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)),
            ).stdout.strip() or "unknown"
        except Exception:
            sha = "unknown"
        _provenance_cache.update(
            jax_version=jax.__version__,
            git_sha=sha,
            device=jax.default_backend(),
        )
    return dict(_provenance_cache)


def with_provenance(rows: list) -> list:
    """Return ``rows`` with the provenance columns filled in (in place).
    ``emit`` does this automatically; tests that feed rows straight to
    scripts/check_bench_json.py call it themselves."""
    prov = provenance()
    for r in rows:
        for k, v in prov.items():
            r.setdefault(k, v)
    return rows


def emit(rows: list, header: bool = False) -> None:
    """Print benchmark rows as CSV; mirror them to REPRO_BENCH_JSON if set.
    Every row is stamped with ``provenance()`` (existing keys win, so a
    bench can override e.g. ``device`` for rows measured elsewhere)."""
    if not rows:
        return
    with_provenance(rows)
    keys = list(rows[0])
    if header:
        print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    path = os.environ.get("REPRO_BENCH_JSON")
    if path:
        _json_rows.extend(rows)
        with open(path, "w") as f:
            json.dump(_json_rows, f, indent=1, default=str)
