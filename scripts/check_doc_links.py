#!/usr/bin/env python
"""Docs link check (CI step + tests/test_bench_smoke.py).

Scans every markdown file at the repo root and under docs/ for relative
markdown links ``[text](target)`` and verifies each target resolves to a
file or directory in the repo.  External schemes (http/https/mailto) and
pure in-page anchors (#...) are skipped; a ``path#anchor`` target is checked
for the path part only (anchor slugs are not validated).  Exits non-zero
listing every broken link.

  python scripts/check_doc_links.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# inline links only; reference-style [text][ref] is not used in this repo.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def doc_files() -> list:
    files = sorted(glob.glob(os.path.join(ROOT, "*.md")))
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "**", "*.md"),
                              recursive=True))
    return files


def check(files) -> list:
    broken = []
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        base = os.path.dirname(path)
        for lineno, line in enumerate(text.splitlines(), 1):
            for m in LINK.finditer(line):
                target = m.group(1)
                if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(os.path.join(base, rel))
                if not os.path.exists(resolved):
                    broken.append(
                        f"{os.path.relpath(path, ROOT)}:{lineno}: "
                        f"broken link -> {target}"
                    )
    return broken


def main() -> int:
    files = doc_files()
    broken = check(files)
    for b in broken:
        print(b)
    print(f"[check_doc_links] {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
