#!/usr/bin/env python
"""Render a serve run's JSONL metrics export into human-readable reports —
the paper's Fig-4/5 recomputed from production (or simulated) traffic.

Input: the JSONL written by ``serve.py --loop --trace --metrics-out run.jsonl``
(or any ``repro.obs.MetricsRegistry.export_jsonl`` snapshot).  Output:

  norm-band heat table — evals per catalog norm decile from the always-on
      ``walk_evals_by_band`` vector; on heavy-tailed (lognormal) catalogs
      the top decile should carry the majority of evals (the paper's Fig-5
      norm-bias claim — printed as ``top_decile_share`` for scripting).
  latency timeline — per-time-bin p50/p99 from the ``response`` event
      timeline ("why did p99 spike at t=3s").
  scalar summary — requests/batches/degrades/misses, hub-eval share, churn
      health gauges when present.

  PYTHONPATH=src python scripts/obs_report.py run.jsonl
  PYTHONPATH=src python scripts/obs_report.py run.jsonl --bins 20
"""
from __future__ import annotations

import argparse
import sys

from repro.obs import render_band_table, render_latency_timeline, top_band_share


def report(path: str, *, n_bins: int = 12, out=sys.stdout) -> dict:
    """Render all sections; returns the scalar summary (tests use it)."""
    from repro.obs import load_jsonl

    snap = load_jsonl(path)
    meta, metrics, events = snap["meta"], snap["metrics"], snap["events"]

    w = out.write
    if meta:
        kv = " ".join(f"{k}={v}" for k, v in meta.items()
                      if k != "band_edges")
        w(f"# meta: {kv}\n")

    summary: dict = {}
    band = metrics.get("walk_evals_by_band")
    if band is not None:
        share = top_band_share(band["values"])
        summary["top_decile_share"] = share
        w("\n== evals by catalog norm band (band 0 = smallest norms) ==\n")
        w(render_band_table(band["values"], meta.get("band_edges"),
                            label="band") + "\n")
        w(f"top_decile_share={share:.4f}\n")
    else:
        w("\n(no walk_evals_by_band vector — run with --trace to get the "
          "norm-bias table)\n")

    w("\n== latency timeline (loop clock) ==\n")
    w(render_latency_timeline(events, n_bins=n_bins) + "\n")

    w("\n== scalars ==\n")
    for name in sorted(metrics):
        m = metrics[name]
        if m["kind"] in ("counter", "gauge"):
            summary[name] = m["value"]
            w(f"{name} = {m['value']:g}\n")
        elif m["kind"] == "histogram" and m["count"]:
            mean = m["sum"] / m["count"]
            summary[name] = mean
            w(f"{name}: count={m['count']} mean={mean:g}\n")

    ev_total = metrics.get("walk_evals_total")
    hub = metrics.get("walk_hub_evals_total")
    if ev_total and hub and ev_total["value"] > 0:
        frac = hub["value"] / ev_total["value"]
        summary["hub_eval_share"] = frac
        w(f"hub_eval_share = {frac:.4f}\n")
    return summary


def main() -> int:
    ap = argparse.ArgumentParser(
        description="render a repro.obs JSONL export (see module docstring)"
    )
    ap.add_argument("jsonl", help="path written by serve.py --metrics-out")
    ap.add_argument("--bins", type=int, default=12,
                    help="latency-timeline time bins")
    args = ap.parse_args()
    report(args.jsonl, n_bins=args.bins)
    return 0


if __name__ == "__main__":
    sys.exit(main())
