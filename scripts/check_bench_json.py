#!/usr/bin/env python
"""Benchmark-smoke JSON gate (CI step).

Fails the benchmark-smoke step when the quick-mode build_bench JSON is
missing the per-tile ``build_phase`` rows the tiled commit grid emits — the
observability contract of DESIGN.md §7 / docs/BENCHMARKS.md: at least one
pallas row with ``commit_tile > 1`` (the reclaiming layout) and one with
``commit_tile == 1`` (the untiled baseline), every row carrying the
``grid_steps`` / ``pad_step_frac`` columns.

  python scripts/check_bench_json.py bench-artifacts/build_bench.json
"""
from __future__ import annotations

import json
import sys

REQUIRED_COLS = {
    "commit_backend", "commit_tile", "find_s", "commit_s", "commit_share",
    "grid_steps", "pad_step_frac",
}


def main(path: str) -> int:
    with open(path) as f:
        rows = json.load(f)
    phase = [r for r in rows if r.get("bench") == "build_phase"]
    if not phase:
        print(f"[check_bench_json] {path}: no build_phase rows at all")
        return 1
    missing = [sorted(REQUIRED_COLS - set(r)) for r in phase if REQUIRED_COLS - set(r)]
    if missing:
        print(f"[check_bench_json] build_phase rows missing columns: {missing[0]}")
        return 1
    tiles = sorted(
        {int(r["commit_tile"]) for r in phase if r["commit_backend"] == "pallas"}
    )
    if 1 not in tiles or not any(t > 1 for t in tiles):
        print(
            "[check_bench_json] need pallas build_phase rows for commit_tile"
            f"=1 AND a tile > 1, got tiles={tiles}"
        )
        return 1
    print(
        f"[check_bench_json] ok: {len(phase)} build_phase rows, "
        f"pallas tiles={tiles}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "build_bench.json"))
