#!/usr/bin/env python
"""Benchmark-smoke JSON gate (CI step).

Validates whichever known row families a quick-mode REPRO_BENCH_JSON file
carries (row schemas: docs/BENCHMARKS.md), failing the step when a family's
observability contract is broken:

  build_phase — the tiled commit grid's contract (DESIGN.md §7): at least
      one pallas row with ``commit_tile > 1`` (the reclaiming layout) and
      one with ``commit_tile == 1`` (the untiled baseline), every row
      carrying the ``grid_steps`` / ``pad_step_frac`` columns.
  serve — the continuous-batching loop's contract (launch/serve_loop.py):
      every row carries the p50/p99/QPS/recall/occupancy/recompile columns,
      serves every request (the loop never rejects), and reports ZERO
      steady-state recompiles — a bucket-ladder regression fails CI here.
  churn — the mutation layer's contract (core/mutation.py): on every
      ``kind=turnover`` row the post-churn, fully-relinked recall@10 must
      sit within 0.02 of the fresh-rebuild floor, no request may be
      rejected during churn, the churned graph must still be compile-once
      (zero steady recompiles), and ``relink_debt`` must reach 0 after the
      full repair.
  obs_overhead — the observability layer's contract (repro.obs, ISSUE 9):
      the always-on metrics registry may cost at most 5% loop wall time
      over an uninstrumented run, traced mode must cause ZERO steady-state
      recompiles (trace shapes are static), and the virtual-clock p50 must
      be identical base-vs-traced (observability must not change
      scheduling).
  shard — the norm-banded routing contract (core/distributed.py, ISSUE 10):
      on the lognormal (heavy norm tail) profile the
      ``norm_bands``+``upper_bound`` rows must actually skip shards
      (``skipped_frac > 0``), cut mean shards visited by >= 30% vs the
      round-robin baseline, and hold recall@10 within 0.01 of it — the
      bound-skip rule is provably recall-free, so any recall gap means the
      routing layer is broken, not "tuned differently".

Additionally EVERY row of EVERY family must carry the provenance columns
``jax_version`` / ``git_sha`` / ``device`` (benchmarks/common.py stamps
them in ``emit``), so artifact trajectories stay attributable.

A file with none of the known families fails outright.

  python scripts/check_bench_json.py bench-artifacts/build_bench.json
  python scripts/check_bench_json.py bench-artifacts/serve_bench.json
"""
from __future__ import annotations

import json
import os
import sys

PHASE_COLS = {
    "commit_backend", "commit_tile", "find_s", "commit_s", "commit_share",
    "grid_steps", "pad_step_frac",
}

SERVE_COLS = {
    "profile", "clock", "rate_qps", "n_requests", "served", "p50_ms",
    "p99_ms", "qps", "recall_at_10", "occupancy", "deadline_miss_frac",
    "recompiles_warmup", "recompiles_steady",
}


def _missing_cols(rows: list, required: set) -> list:
    return [sorted(required - set(r)) for r in rows if required - set(r)]


def check_build_phase(rows: list) -> list:
    errors = []
    missing = _missing_cols(rows, PHASE_COLS)
    if missing:
        errors.append(f"build_phase rows missing columns: {missing[0]}")
        return errors
    tiles = sorted(
        {int(r["commit_tile"]) for r in rows if r["commit_backend"] == "pallas"}
    )
    if 1 not in tiles or not any(t > 1 for t in tiles):
        errors.append(
            "need pallas build_phase rows for commit_tile=1 AND a tile > 1, "
            f"got tiles={tiles}"
        )
    return errors


def check_serve(rows: list) -> list:
    errors = []
    missing = _missing_cols(rows, SERVE_COLS)
    if missing:
        errors.append(f"serve rows missing columns: {missing[0]}")
        return errors
    for r in rows:
        tag = f"serve[{r.get('profile')},{r.get('clock')}]"
        if int(r["recompiles_steady"]) != 0:
            errors.append(
                f"{tag}: {r['recompiles_steady']} steady-state recompiles — "
                "the bucket ladder is no longer compile-once"
            )
        if int(r["served"]) != int(r["n_requests"]):
            errors.append(
                f"{tag}: served {r['served']} of {r['n_requests']} requests "
                "— the loop must degrade, never reject"
            )
        if not 0.0 < float(r["recall_at_10"]) <= 1.0:
            errors.append(f"{tag}: implausible recall {r['recall_at_10']}")
        if not 0.0 < float(r["occupancy"]) <= 1.0:
            errors.append(f"{tag}: implausible occupancy {r['occupancy']}")
        if float(r["p50_ms"]) > float(r["p99_ms"]):
            errors.append(f"{tag}: p50 {r['p50_ms']} > p99 {r['p99_ms']}")
    return errors


CHURN_COLS = {
    "profile", "kind", "recall_at_10", "dead_edge_frac", "relink_debt",
}

CHURN_TURNOVER_COLS = CHURN_COLS | {
    "turnover", "rejected", "recompiles_steady", "recall_floor",
    "recall_delta", "mutation_events",
}

# Maximum recall@10 a fully-relinked mutated index may sit below a fresh
# rebuild of the same catalog (ISSUE acceptance bar).
CHURN_RECALL_SLACK = 0.02


def check_churn(rows: list) -> list:
    errors = []
    missing = _missing_cols(rows, CHURN_COLS)
    if missing:
        errors.append(f"churn rows missing columns: {missing[0]}")
        return errors
    turnover = [r for r in rows if r["kind"] == "turnover"]
    if not turnover:
        errors.append("churn family needs at least one kind=turnover row")
    missing = _missing_cols(turnover, CHURN_TURNOVER_COLS)
    if missing:
        errors.append(f"churn turnover rows missing columns: {missing[0]}")
        return errors
    for r in turnover:
        tag = f"churn[{r.get('profile')},turnover={r.get('turnover')}]"
        if int(r["rejected"]) != 0:
            errors.append(
                f"{tag}: {r['rejected']} requests rejected during churn — "
                "the loop must degrade, never reject"
            )
        if int(r["recompiles_steady"]) != 0:
            errors.append(
                f"{tag}: {r['recompiles_steady']} steady-state recompiles — "
                "mutation must stay fixed-shape / compile-once"
            )
        if int(r["mutation_events"]) <= 0:
            errors.append(f"{tag}: no mutation events applied")
        if int(r["relink_debt"]) != 0:
            errors.append(
                f"{tag}: relink_debt {r['relink_debt']} after full repair"
            )
        delta = float(r["recall_at_10"]) - float(r["recall_floor"])
        if delta < -CHURN_RECALL_SLACK:
            errors.append(
                f"{tag}: post-churn recall {r['recall_at_10']} is "
                f"{-delta:.4f} below the fresh-build floor "
                f"{r['recall_floor']} (budget {CHURN_RECALL_SLACK})"
            )
        if not 0.0 < float(r["recall_at_10"]) <= 1.0:
            errors.append(f"{tag}: implausible recall {r['recall_at_10']}")
    return errors


OBS_COLS = {
    "profile", "base_wall_s", "metrics_wall_s", "traced_wall_s",
    "metrics_overhead_frac", "traced_overhead_frac", "p50_ms_base",
    "p50_ms_traced", "recompiles_steady_traced", "top_band_share",
}

# Always-on metrics must stay under this fraction of loop wall time
# (ISSUE 9 acceptance bar).  The env override exists for callers that run
# the gate on a machine already under load (tests run the bench in-process
# alongside the rest of the suite, where wall-ratio noise swamps the real
# ~0.1% registry cost); CI's dedicated bench step uses the strict default.
OBS_OVERHEAD_BUDGET = float(
    os.environ.get("REPRO_OBS_OVERHEAD_BUDGET", "0.05")
)

# Virtual-clock p50s are analytically identical base-vs-traced; a tiny eps
# absorbs float printing, nothing more.
OBS_P50_EPS = 1e-6


def check_obs_overhead(rows: list) -> list:
    errors = []
    missing = _missing_cols(rows, OBS_COLS)
    if missing:
        errors.append(f"obs_overhead rows missing columns: {missing[0]}")
        return errors
    for r in rows:
        tag = f"obs_overhead[{r.get('profile')}]"
        frac = float(r["metrics_overhead_frac"])
        if frac > OBS_OVERHEAD_BUDGET:
            errors.append(
                f"{tag}: always-on metrics cost {frac:.1%} of loop wall "
                f"time (budget {OBS_OVERHEAD_BUDGET:.0%}) — the registry "
                "path is no longer cheap enough to leave on"
            )
        if int(r["recompiles_steady_traced"]) != 0:
            errors.append(
                f"{tag}: {r['recompiles_steady_traced']} steady-state "
                "recompiles with tracing on — trace shapes are no longer "
                "static"
            )
        dp50 = abs(float(r["p50_ms_base"]) - float(r["p50_ms_traced"]))
        if dp50 > OBS_P50_EPS:
            errors.append(
                f"{tag}: virtual p50 diverged base={r['p50_ms_base']} vs "
                f"traced={r['p50_ms_traced']} — observability changed the "
                "schedule"
            )
        if not 0.0 <= float(r["top_band_share"]) <= 1.0:
            errors.append(
                f"{tag}: implausible top_band_share {r['top_band_share']}"
            )
    return errors


SHARD_COLS = {
    "profile", "norm_profile", "partition", "route", "storage", "n_shards",
    "shards_visited_mean", "skipped_frac", "evals_per_query", "recall_at_10",
    "visited_saved_frac", "evals_saved_frac",
}

# ISSUE-10 acceptance bar: on the heavy-norm-tail profile, upper-bound
# routing must cut mean shards visited by at least this fraction vs the
# round-robin baseline, at equal recall (within SHARD_RECALL_SLACK).
SHARD_VISITED_SAVINGS = 0.30
SHARD_RECALL_SLACK = 0.01


def check_shard(rows: list) -> list:
    errors = []
    missing = _missing_cols(rows, SHARD_COLS)
    if missing:
        errors.append(f"shard rows missing columns: {missing[0]}")
        return errors
    # Pair every routed norm_bands row with the roundrobin baseline of its
    # (profile, index, n, n_shards) group.
    groups: dict = {}
    for r in rows:
        groups.setdefault(
            (r["profile"], r.get("index"), r.get("n"), r["n_shards"]), []
        ).append(r)
    for key, group in groups.items():
        tag = f"shard[{key[0]}]"
        baselines = [r for r in group
                     if r["partition"] == "roundrobin" and r["route"] == "none"]
        routed = [r for r in group
                  if r["partition"] == "norm_bands"
                  and r["route"] == "upper_bound"]
        if not baselines:
            errors.append(f"{tag}: no roundrobin route=none baseline row")
            continue
        if not routed:
            errors.append(f"{tag}: no norm_bands route=upper_bound row")
            continue
        base = baselines[0]
        lognormal = all(r["norm_profile"] == "lognormal" for r in group)
        for r in routed:
            rtag = f"{tag}[storage={r.get('storage')}]"
            drecall = float(r["recall_at_10"]) - float(base["recall_at_10"])
            if drecall < -SHARD_RECALL_SLACK:
                errors.append(
                    f"{rtag}: routed recall {r['recall_at_10']} is "
                    f"{-drecall:.4f} below the roundrobin baseline "
                    f"{base['recall_at_10']} (budget {SHARD_RECALL_SLACK}) — "
                    "the skip rule dropped a shard that could contribute"
                )
            if not lognormal:
                continue
            if float(r["skipped_frac"]) <= 0.0:
                errors.append(
                    f"{rtag}: skipped_frac == 0 under the lognormal profile "
                    "— the norm bias must produce bound skips"
                )
            saved = 1.0 - (
                float(r["shards_visited_mean"])
                / float(base["shards_visited_mean"])
            )
            if saved < SHARD_VISITED_SAVINGS:
                errors.append(
                    f"{rtag}: routing saved only {saved:.1%} of shard visits "
                    f"vs roundrobin (bar {SHARD_VISITED_SAVINGS:.0%}, "
                    f"{r['shards_visited_mean']} vs "
                    f"{base['shards_visited_mean']})"
                )
        for r in group:
            if not 0.0 < float(r["recall_at_10"]) <= 1.0:
                errors.append(
                    f"{tag}: implausible recall {r['recall_at_10']} "
                    f"(partition={r['partition']}, route={r['route']})"
                )
    return errors


PROVENANCE_COLS = {"jax_version", "git_sha", "device"}


def check_provenance(rows: list) -> list:
    """Every row of every family must be attributable
    (benchmarks/common.py::provenance)."""
    bad = [
        (i, sorted(PROVENANCE_COLS - set(r)))
        for i, r in enumerate(rows)
        if PROVENANCE_COLS - set(r)
    ]
    if bad:
        i, cols = bad[0]
        return [
            f"{len(bad)} row(s) missing provenance columns "
            f"(first: row {i} lacks {cols}) — emit through "
            "benchmarks/common.py or stamp with with_provenance()"
        ]
    return []


FAMILIES = {
    "build_phase": check_build_phase,
    "serve": check_serve,
    "churn": check_churn,
    "obs_overhead": check_obs_overhead,
    "shard": check_shard,
}


def main(path: str) -> int:
    with open(path) as f:
        rows = json.load(f)
    checked = []
    errors = check_provenance(rows)
    for family, check in FAMILIES.items():
        fam_rows = [r for r in rows if r.get("bench") == family]
        if not fam_rows:
            continue
        checked.append(f"{family}({len(fam_rows)})")
        errors.extend(check(fam_rows))
    if not checked:
        print(f"[check_bench_json] {path}: no known row families "
              f"(expected one of {sorted(FAMILIES)})")
        return 1
    for e in errors:
        print(f"[check_bench_json] {e}")
    if errors:
        return 1
    print(f"[check_bench_json] ok: {', '.join(checked)} rows validated")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "build_bench.json"))
