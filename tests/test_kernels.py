"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.gather_score import gather_score, gather_score_ref
from repro.kernels.mips_topk import mips_topk, mips_topk_ref
from repro.kernels.topk_merge import topk_merge, topk_merge_ref


@pytest.mark.parametrize(
    "b,n,d,k",
    [
        (1, 200, 16, 5),
        (7, 1000, 48, 10),
        (32, 4096, 300, 10),
        (128, 777, 150, 20),
        (9, 513, 384, 1),
    ],
)
def test_mips_topk_matches_ref(rng, b, n, d, k):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    vs, ids = mips_topk(q, x, k=k)
    rvs, rids = mips_topk_ref(q, x, k=k)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(rvs), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_mips_topk_dtypes(rng, dtype):
    q = jnp.asarray(rng.normal(size=(4, 64)).astype(dtype))
    x = jnp.asarray(rng.normal(size=(512, 64)).astype(dtype))
    vs, ids = mips_topk(q, x, k=8)
    rvs, rids = mips_topk_ref(q.astype(jnp.float32), x.astype(jnp.float32), k=8)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(rvs), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize(
    "b,n,d,w",
    [(1, 50, 7, 3), (4, 100, 33, 7), (16, 512, 300, 16), (64, 2048, 128, 32)],
)
def test_gather_score_matches_ref(rng, b, n, d, w):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, size=(b, w)).astype(np.int32))
    s = gather_score(q, x, ids)
    r = gather_score_ref(q, x, ids)
    np.testing.assert_allclose(np.asarray(s), np.asarray(r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,l,m", [(1, 8, 4), (5, 16, 8), (130, 64, 16), (64, 32, 32)])
def test_topk_merge_matches_ref(rng, b, l, m):
    args = (
        rng.normal(size=(b, l)).astype(np.float32),
        rng.integers(0, 1000, (b, l)).astype(np.int32),
        rng.integers(0, 2, (b, l)).astype(np.int32),
        rng.normal(size=(b, m)).astype(np.float32),
        rng.integers(0, 1000, (b, m)).astype(np.int32),
        rng.integers(0, 2, (b, m)).astype(np.int32),
    )
    out = topk_merge(*map(jnp.asarray, args))
    ref = topk_merge_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    assert np.array_equal(np.asarray(out[2]), np.asarray(ref[2]))


def test_gather_score_is_beam_search_compatible(rng):
    """gather_score can replace similarity.gather_scores as score_fn."""
    from repro.core.graph import empty_graph
    from repro.core.search import beam_search
    from repro.core.build import build_graph
    import functools

    items = jnp.asarray(rng.normal(size=(300, 16)).astype(np.float32))
    g = build_graph(items, max_degree=8, ef_construction=16, insert_batch=64)
    q = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (5, 1)).astype(jnp.int32)
    r1 = beam_search(g, q, init, pool_size=16, max_steps=32, k=5)
    r2 = beam_search(
        g, q, init, pool_size=16, max_steps=32, k=5,
        score_fn=functools.partial(gather_score),
    )
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))


@pytest.mark.parametrize(
    "s,t,hd,off,win",
    [(128, 128, 64, 0, None), (128, 256, 64, 128, None),
     (128, 128, 64, 0, 32), (256, 256, 128, 0, None)],
)
def test_flash_attn_head_matches_ref(rng, s, t, hd, off, win):
    from repro.kernels.flash_attn import (
        flash_attention_head,
        flash_attention_head_ref,
    )

    q = jnp.asarray(rng.normal(size=(s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(t, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(t, hd)).astype(np.float32))
    out = flash_attention_head(q, k, v, q_offset=off, window=win, bq=64, bk=64)
    ref = flash_attention_head_ref(q, k, v, q_offset=off, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_attn_gqa_wrapper(rng):
    from repro.kernels.flash_attn import flash_attention, flash_attention_head_ref

    B, S, H, KV, hd = 2, 128, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    out = np.asarray(flash_attention(q, k, v, bq=64, bk=64)).reshape(B, S, KV, H // KV, hd)
    qg = np.asarray(q.reshape(B, S, KV, H // KV, hd))
    for b in range(B):
        for n in range(KV):
            for g in range(H // KV):
                ref = flash_attention_head_ref(
                    jnp.asarray(qg[b, :, n, g]), k[b, :, n], v[b, :, n]
                )
                np.testing.assert_allclose(out[b, :, n, g], np.asarray(ref),
                                           rtol=2e-5, atol=2e-5)


def test_flash_attn_jnp_path_matches_block(rng):
    """models/layers.py jnp flash (custom_vjp) vs the dense block oracle."""
    from repro.models import layers as L

    qg = jnp.asarray(rng.normal(size=(2, 8, 2, 3, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 32, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 32, 2, 16)).astype(np.float32))
    q_pos = jnp.arange(8, 16, dtype=jnp.int32)
    k_pos = jnp.arange(32, dtype=jnp.int32)
    import jax

    for w in (None, 5):
        ref = L._attend_block(qg, k, v, q_pos, k_pos, w)
        out = L._attend_flash(qg, k, v, q_pos, k_pos, w, 8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
        for argnum in (0, 1, 2):
            g1 = jax.grad(
                lambda *a: jnp.sum(L._attend_block(*a, q_pos, k_pos, w) ** 2),
                argnums=argnum,
            )(qg, k, v)
            g2 = jax.grad(
                lambda *a: jnp.sum(L._attend_flash(*a, q_pos, k_pos, w, 8) ** 2),
                argnums=argnum,
            )(qg, k, v)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                       rtol=1e-4, atol=1e-4)
