"""Benchmark-surface smoke: the build_bench phase-split rows must show the
tiled commit grid actually reclaiming pad steps (the ISSUE-5 acceptance
knob), the serve_bench rows must carry the serving-loop schema with zero
steady-state recompiles (the ISSUE-6 acceptance knob), the obs_overhead
row must hold the observability budget (the ISSUE-9 acceptance knob), and
the docs link-check script CI runs must pass on the repo itself.

The bench import needs the repo root on sys.path (tests run with
PYTHONPATH=src); benchmarks/ is resolved relative to this file so the test
works from any CWD.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)


@pytest.mark.slow
def test_build_bench_quick_pad_step_frac_drops():
    """--quick-sized phase split, pallas backend only: the tiled rows'
    pad_step_frac must drop below 0.5 (acceptance asks ≤ 0.25 at the full
    paper-scale schedule; the CI-sized schedule is granted slack), and the
    untiled T=1 row must stay the expensive baseline the tiling reclaims."""
    from benchmarks.build_bench import phase_split_rows
    from repro.core.build import resolve_commit_tile

    rows = phase_split_rows(
        "word_like", quick=True, backends=("pallas",), tiles=(1, 8)
    )
    by_tile = {r["commit_tile"]: r for r in rows}
    assert set(by_tile) == {1, 8}
    for r in rows:
        assert r["bench"] == "build_phase"
        assert set(r) >= {"commit_tile", "grid_steps", "pad_step_frac",
                          "find_s", "commit_s", "commit_share"}
    # the historical untiled waste is still visible at T=1...
    assert by_tile[1]["pad_step_frac"] > 0.5
    # ...and the tiled grid reclaims it
    assert by_tile[8]["pad_step_frac"] < 0.5
    assert by_tile[8]["pad_step_frac"] < by_tile[1]["pad_step_frac"]
    assert by_tile[8]["grid_steps"] < by_tile[1]["grid_steps"]
    # the auto planner picks a reclaiming tile (> 1) on a word_like-shaped
    # heavy norm tail (the actual planner path, not the no-data fallback)
    import numpy as np
    heavy = np.exp(np.random.default_rng(0).normal(size=2000))
    assert resolve_commit_tile("auto", norms=heavy) > 1


def test_serve_bench_quick_row_schema_and_zero_steady_recompiles():
    """The quick serve_bench rows must carry the docs/BENCHMARKS.md serve
    schema, serve every request, and report ZERO steady-state recompiles
    (the bucket ladder is compile-once) — and the CI gate script itself
    must accept them."""
    import json
    import tempfile

    from benchmarks import common
    from benchmarks.serve_bench import serve_rows

    rows = serve_rows("word_like", quick=True)
    assert rows and all(r["bench"] == "serve" for r in rows)
    (row,) = rows
    assert row["served"] == row["n_requests"]       # degrade, never reject
    assert row["recompiles_steady"] == 0            # compile-once ladder
    assert row["recompiles_warmup"] > 0             # ...but it DID compile
    assert 0.0 < row["occupancy"] <= 1.0
    assert 0.0 < row["recall_at_10"] <= 1.0
    assert row["p50_ms"] <= row["p99_ms"]
    assert row["clock"] == "virtual"                # CI stays deterministic

    # the same rows must pass the CI gate script — including its provenance
    # requirement, which emit() normally handles (ISSUE-9)
    check = os.path.join(ROOT, "scripts", "check_bench_json.py")
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(common.with_provenance(rows), f)
        path = f.name
    try:
        res = subprocess.run(
            [sys.executable, check, path], capture_output=True, text=True
        )
        assert res.returncode == 0, res.stdout + res.stderr
    finally:
        os.unlink(path)


def test_obs_overhead_bench_row_passes_gate():
    """The ISSUE-9 observability contract row: ZERO steady recompiles with
    tracing on, virtual p50 identical base-vs-traced, the lognormal
    top-band share showing the Fig-5 majority — plus the CI gate script
    accepting the row.  The 5% wall-time budget itself is CI's dedicated
    (uncontended) bench step's job: inside a loaded test process the
    base-vs-metrics wall ratio is machine noise, so the gate subprocess
    runs with the budget relaxed via REPRO_OBS_OVERHEAD_BUDGET and this
    test only sanity-bounds the fraction."""
    import json
    import tempfile

    from benchmarks import common
    from benchmarks.serve_bench import obs_overhead_rows

    rows = obs_overhead_rows("word_like", quick=True)
    (row,) = rows
    assert row["bench"] == "obs_overhead"
    assert row["recompiles_steady_traced"] == 0
    assert row["p50_ms_base"] == row["p50_ms_traced"]
    assert -0.5 < row["metrics_overhead_frac"] < 2.0
    assert row["top_band_share"] > 0.5              # norm bias, live
    assert row["base_wall_s"] > 0

    check = os.path.join(ROOT, "scripts", "check_bench_json.py")
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(common.with_provenance(rows), f)
        path = f.name
    try:
        res = subprocess.run(
            [sys.executable, check, path], capture_output=True, text=True,
            env=dict(os.environ, REPRO_OBS_OVERHEAD_BUDGET="2.0"),
        )
        assert res.returncode == 0, res.stdout + res.stderr
    finally:
        os.unlink(path)


def test_docs_link_check_passes():
    """CI runs scripts/check_doc_links.py; keep it green from the suite too
    so a broken relative link fails before the PR hits CI."""
    script = os.path.join(ROOT, "scripts", "check_doc_links.py")
    res = subprocess.run(
        [sys.executable, script], cwd=ROOT, capture_output=True, text=True
    )
    assert res.returncode == 0, res.stdout + res.stderr
