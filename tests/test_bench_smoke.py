"""Benchmark-surface smoke: the build_bench phase-split rows must show the
tiled commit grid actually reclaiming pad steps (the ISSUE-5 acceptance
knob), the serve_bench rows must carry the serving-loop schema with zero
steady-state recompiles (the ISSUE-6 acceptance knob), and the docs
link-check script CI runs must pass on the repo itself.

The bench import needs the repo root on sys.path (tests run with
PYTHONPATH=src); benchmarks/ is resolved relative to this file so the test
works from any CWD.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)


@pytest.mark.slow
def test_build_bench_quick_pad_step_frac_drops():
    """--quick-sized phase split, pallas backend only: the tiled rows'
    pad_step_frac must drop below 0.5 (acceptance asks ≤ 0.25 at the full
    paper-scale schedule; the CI-sized schedule is granted slack), and the
    untiled T=1 row must stay the expensive baseline the tiling reclaims."""
    from benchmarks.build_bench import phase_split_rows
    from repro.core.build import resolve_commit_tile

    rows = phase_split_rows(
        "word_like", quick=True, backends=("pallas",), tiles=(1, 8)
    )
    by_tile = {r["commit_tile"]: r for r in rows}
    assert set(by_tile) == {1, 8}
    for r in rows:
        assert r["bench"] == "build_phase"
        assert set(r) >= {"commit_tile", "grid_steps", "pad_step_frac",
                          "find_s", "commit_s", "commit_share"}
    # the historical untiled waste is still visible at T=1...
    assert by_tile[1]["pad_step_frac"] > 0.5
    # ...and the tiled grid reclaims it
    assert by_tile[8]["pad_step_frac"] < 0.5
    assert by_tile[8]["pad_step_frac"] < by_tile[1]["pad_step_frac"]
    assert by_tile[8]["grid_steps"] < by_tile[1]["grid_steps"]
    # the auto planner picks a reclaiming tile (> 1) on a word_like-shaped
    # heavy norm tail (the actual planner path, not the no-data fallback)
    import numpy as np
    heavy = np.exp(np.random.default_rng(0).normal(size=2000))
    assert resolve_commit_tile("auto", norms=heavy) > 1


def test_serve_bench_quick_row_schema_and_zero_steady_recompiles():
    """The quick serve_bench rows must carry the docs/BENCHMARKS.md serve
    schema, serve every request, and report ZERO steady-state recompiles
    (the bucket ladder is compile-once) — and the CI gate script itself
    must accept them."""
    import json
    import tempfile

    from benchmarks.serve_bench import serve_rows

    rows = serve_rows("word_like", quick=True)
    assert rows and all(r["bench"] == "serve" for r in rows)
    (row,) = rows
    assert row["served"] == row["n_requests"]       # degrade, never reject
    assert row["recompiles_steady"] == 0            # compile-once ladder
    assert row["recompiles_warmup"] > 0             # ...but it DID compile
    assert 0.0 < row["occupancy"] <= 1.0
    assert 0.0 < row["recall_at_10"] <= 1.0
    assert row["p50_ms"] <= row["p99_ms"]
    assert row["clock"] == "virtual"                # CI stays deterministic

    # the same rows must pass the CI gate script
    check = os.path.join(ROOT, "scripts", "check_bench_json.py")
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(rows, f)
        path = f.name
    try:
        res = subprocess.run(
            [sys.executable, check, path], capture_output=True, text=True
        )
        assert res.returncode == 0, res.stdout + res.stderr
    finally:
        os.unlink(path)


def test_docs_link_check_passes():
    """CI runs scripts/check_doc_links.py; keep it green from the suite too
    so a broken relative link fails before the PR hits CI."""
    script = os.path.join(ROOT, "scripts", "check_doc_links.py")
    res = subprocess.run(
        [sys.executable, script], cwd=ROOT, capture_output=True, text=True
    )
    assert res.returncode == 0, res.stdout + res.stderr
