"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture()
def rng():
    # Function-scoped so every test draws the same stream regardless of which
    # other tests ran before it (a session-scoped generator made draws depend
    # on collection order).
    return np.random.default_rng(0)
