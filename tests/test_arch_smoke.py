"""Per-assigned-architecture smoke tests: REDUCED configs of the same family
(small widths/depths/tables/graphs) run one forward/train step on CPU,
asserting output shapes + no NaNs.  The FULL configs are exercised only via
the dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.models.recsys import dien as dien_mod
from repro.models.recsys import dlrm as dlrm_mod
from repro.models.recsys import mind as mind_mod
from repro.models.recsys import sasrec as sasrec_mod
from repro.train import adamw_init, adamw_update

RNG = np.random.default_rng(0)


def _reduced_lm(arch_id):
    cfg = get_arch(arch_id).cfg
    pat = cfg.window_pattern
    if any(w is not None for w in pat):
        pat = tuple((8 if w is not None else None) for w in pat)  # tiny windows
    return dataclasses.replace(
        cfg,
        n_layers=2 * len(pat),
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=96 if cfg.is_moe else 128,
        vocab=256,
        moe_experts=4 if cfg.is_moe else 0,
        moe_top_k=2 if cfg.is_moe else 0,
        window_pattern=pat,
        dtype=jnp.float32,
        attn_chunk=8,
        remat=False,
    )


LM_ARCHS = [
    "internlm2-20b",
    "gemma3-12b",
    "granite-3-2b",
    "qwen3-moe-235b-a22b",
    "grok-1-314b",
]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_train_step(arch_id):
    cfg = _reduced_lm(arch_id)
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)).astype(np.int32))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    loss, grads = jax.value_and_grad(tf.lm_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    p2, o2 = adamw_update(grads, opt, params, lr=1e-3)
    l2 = tf.lm_loss(p2, batch, cfg)
    assert np.isfinite(float(l2))


@pytest.mark.parametrize("arch_id", ["gemma3-12b", "granite-3-2b"])
def test_lm_arch_decode_consistency(arch_id):
    """prefill + decode == full forward on the last token (incl. sliding
    window ring cache for gemma3's hybrid pattern)."""
    cfg = _reduced_lm(arch_id)
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    lg_full, _ = tf.forward(params, toks, cfg)
    lg_pref, caches = tf.serve_prefill(params, toks, cfg, max_len=32)
    np.testing.assert_allclose(
        np.asarray(lg_full[:, -1]), np.asarray(lg_pref), rtol=5e-3, atol=5e-3
    )
    nxt = jnp.argmax(lg_pref, -1)[:, None].astype(jnp.int32)
    lg_dec, _ = tf.serve_step(params, caches, nxt, jnp.int32(S), cfg)
    lg_full2, _ = tf.forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    np.testing.assert_allclose(
        np.asarray(lg_full2[:, -1]), np.asarray(lg_dec), rtol=5e-3, atol=5e-3
    )


def test_gemma3_long_decode_ring_cache():
    """Decode far past the sliding window: ring cache stays exact vs full
    forward."""
    cfg = _reduced_lm("gemma3-12b")  # window 8
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    B, S, EXTRA = 1, 16, 9
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    _, caches = tf.serve_prefill(params, toks, cfg, max_len=32)
    cur = toks
    for i in range(EXTRA):
        lg_full, _ = tf.forward(params, cur, cfg)
        nxt = jnp.argmax(lg_full[:, -1], -1)[:, None].astype(jnp.int32)
        lg_dec, caches = tf.serve_step(params, caches, nxt, jnp.int32(S + i), cfg)
        cur = jnp.concatenate([cur, nxt], axis=1)
        lg_full2, _ = tf.forward(params, cur, cfg)
        np.testing.assert_allclose(
            np.asarray(lg_full2[:, -1]), np.asarray(lg_dec), rtol=5e-3, atol=5e-3
        )


def test_grok_expert_split_is_exact():
    """split=2 half-experts reproduce the unsplit MoE exactly (SwiGLU
    column split)."""
    from repro.models import moe as M

    d, f, E = 16, 32, 4
    key = jax.random.PRNGKey(0)
    params, _ = M.moe_init(key, d, f, E, jnp.float32, expert_split=1)
    # build the split variant from the SAME weights
    split_params = {
        "router": params["router"],
        "w_gate": params["w_gate"].reshape(E, d, 2, f // 2).transpose(0, 2, 1, 3).reshape(2 * E, d, f // 2),
        "w_in": params["w_in"].reshape(E, d, 2, f // 2).transpose(0, 2, 1, 3).reshape(2 * E, d, f // 2),
        "w_out": params["w_out"].reshape(E, 2, f // 2, d).reshape(2 * E, f // 2, d),
    }
    x = jnp.asarray(RNG.normal(size=(2, 8, d)).astype(np.float32))
    # capacity must be >= all tokens so nothing drops in either variant
    o1, _ = M.moe_apply(params, x, n_experts=E, top_k=2, capacity_factor=8.0)
    o2, _ = M.moe_apply(
        split_params, x, n_experts=E, top_k=2, capacity_factor=8.0, expert_split=2
    )
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)


def test_meshgraphnet_all_shapes_reduced():
    arch = get_arch("meshgraphnet")
    cfg = dataclasses.replace(arch.base, n_layers=3, d_hidden=32, d_feat=12, d_edge=4)
    params, _ = gnn_mod.init(jax.random.PRNGKey(0), cfg)
    for n, e in [(50, 200), (128, 64 * 2)]:
        graph = dict(
            node_feat=jnp.asarray(RNG.normal(size=(n, 12)).astype(np.float32)),
            edge_feat=jnp.asarray(RNG.normal(size=(e, 4)).astype(np.float32)),
            src=jnp.asarray(RNG.integers(0, n, e).astype(np.int32)),
            dst=jnp.asarray(RNG.integers(0, n, e).astype(np.int32)),
            targets=jnp.asarray(RNG.normal(size=(n, 3)).astype(np.float32)),
        )
        out = gnn_mod.forward(params, graph, cfg)
        assert out.shape == (n, 3)
        assert not bool(jnp.isnan(out).any())
        loss, grads = jax.value_and_grad(gnn_mod.mse_loss)(params, graph, cfg)
        assert np.isfinite(float(loss))


def test_meshgraphnet_sampled_subgraph():
    """minibatch_lg path: the real fanout sampler feeds the same GNN."""
    from repro.models.sampler import fanout_budget, random_csr, sample_subgraph

    rng = np.random.default_rng(0)
    csr = random_csr(500, 6, rng)
    budget = fanout_budget(8, (4, 3))
    sub = sample_subgraph(csr, rng.integers(0, 500, 8), (4, 3), rng, pad_to=budget)
    cfg = gnn_mod.GNNConfig(n_layers=2, d_hidden=16, d_feat=8, d_edge=4)
    params, _ = gnn_mod.init(jax.random.PRNGKey(0), cfg)
    n = budget[0]
    graph = dict(
        node_feat=jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32)),
        edge_feat=jnp.asarray(rng.normal(size=(budget[1], 4)).astype(np.float32)),
        src=jnp.asarray(sub["src"]),
        dst=jnp.asarray(sub["dst"]),
        targets=jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    )
    out = gnn_mod.forward(params, graph, cfg)
    assert not bool(jnp.isnan(out).any())


_RECSYS = {
    "dlrm-rm2": (dlrm_mod, dlrm_mod.DLRMConfig(n_rows=500), dlrm_mod.bce_loss),
    "sasrec": (sasrec_mod, sasrec_mod.SASRecConfig(n_items=500), sasrec_mod.sampled_softmax_loss),
    "mind": (mind_mod, mind_mod.MINDConfig(n_items=500), mind_mod.sampled_softmax_loss),
    "dien": (dien_mod, dien_mod.DIENConfig(n_items=500), dien_mod.bce_loss),
}


def _recsys_batch(arch_id, cfg, b=4):
    if arch_id == "dlrm-rm2":
        return dict(
            dense=jnp.asarray(RNG.normal(size=(b, cfg.n_dense)).astype(np.float32)),
            sparse=jnp.asarray(RNG.integers(0, cfg.n_rows, (b, cfg.n_sparse)).astype(np.int32)),
            labels=jnp.asarray(RNG.integers(0, 2, b).astype(np.float32)),
        )
    s = cfg.seq_len
    base = dict(hist=jnp.asarray(RNG.integers(-1, 500, (b, s)).astype(np.int32)))
    if arch_id == "sasrec":
        base.update(
            pos=jnp.asarray(RNG.integers(0, 500, (b, s)).astype(np.int32)),
            neg=jnp.asarray(RNG.integers(0, 500, (b, s, 4)).astype(np.int32)),
        )
    elif arch_id == "mind":
        base.update(
            pos=jnp.asarray(RNG.integers(0, 500, b).astype(np.int32)),
            neg=jnp.asarray(RNG.integers(0, 500, (b, 20)).astype(np.int32)),
        )
    else:
        base.update(
            target=jnp.asarray(RNG.integers(0, 500, b).astype(np.int32)),
            labels=jnp.asarray(RNG.integers(0, 2, b).astype(np.float32)),
            aux_neg=jnp.asarray(RNG.integers(0, 500, (b, s)).astype(np.int32)),
        )
    return base


@pytest.mark.parametrize("arch_id", list(_RECSYS))
def test_recsys_arch_train_and_retrieval(arch_id):
    mod, cfg, loss_fn = _RECSYS[arch_id]
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _recsys_batch(arch_id, cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)), arch_id
    opt = adamw_init(params)
    p2, _ = adamw_update(grads, opt, params, lr=1e-3)
    assert np.isfinite(float(loss_fn(p2, batch, cfg)))
    if arch_id != "dlrm-rm2":
        sc = mod.retrieval_scores(params, batch["hist"][:2], cfg)
        assert sc.shape == (2, cfg.n_items)
        assert not bool(jnp.isnan(sc).any())


def test_mind_interests_shape():
    mod, cfg, _ = _RECSYS["mind"]
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    hist = jnp.asarray(RNG.integers(-1, 500, (3, cfg.seq_len)).astype(np.int32))
    caps = mod.interest_capsules(params, hist, cfg)
    assert caps.shape == (3, cfg.n_interests, cfg.embed_dim)
    # squash keeps capsule norms < 1
    assert float(jnp.linalg.norm(caps, axis=-1).max()) <= 1.0 + 1e-5
