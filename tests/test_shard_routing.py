"""Routing-correctness matrix for norm-banded sharding (core/distributed.py).

Pins the two contracts the shard-routing layer stands on:

  1. With routing DISABLED the banded ``sharded_search`` (shard_map, forced
     host devices) is bit-identical to ``sharded_search_reference`` — the
     partition changes WHERE items live, never what the merge returns.
  2. With routing ENABLED (``route="upper_bound"``) recall@10 stays within
     0.01 of the exhaustive merge: a shard is skipped only when its
     Cauchy-Schwarz bound ``max_norm_s * ||q||`` proves it cannot beat the
     current k-th score, so skips must be recall-free by construction.

plus unit pins on the skip rule itself (skip IFF bound < kth, ties visit)
and the PR-2 pad-id regression re-run on the banded path (all-negative
scores, ragged tail shard).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

from repro.core.distributed import (
    RouteStats,
    build_sharded,
    norm_band_partition,
    shard_visit_mask,
    sharded_search_reference,
)
from repro.data.synthetic import mips_dataset, mips_queries


def _recall(ids, gt, k=10):
    ids, gt = np.asarray(ids), np.asarray(gt)
    return np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(ids, gt)]
    )


def _exact_topk(items, queries, k=10):
    scores = np.asarray(items) @ np.asarray(queries).T
    return np.argsort(-scores, axis=0)[:k].T


def _run(code: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# 1+2. the full matrix, device path vs oracle, one subprocess
# ---------------------------------------------------------------------------


def test_shard_routing_matrix(rng):
    """{gaussian, lognormal} x {ipnsw, ipnsw+} x {f32, int8} x
    {reference, pallas}: banded sharded_search == oracle bit-for-bit with
    route="none", and routed recall@10 within 0.01 of the exhaustive merge.

    One subprocess loops all combos (4 forced host devices): the 4 index
    builds dominate the cost, every (storage, backend) cell reuses them.
    REPRO_TEST_QUICK=1 drops the gaussian profile — the lognormal half is
    the one with real norm spread, and the gaussian half exercises no extra
    code path.
    """
    seed = int(rng.integers(0, 2**31))
    profiles = '("lognormal",)' if QUICK else '("gaussian", "lognormal")'
    _run(
        f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import build_sharded, sharded_search, sharded_search_reference
from repro.data.synthetic import mips_dataset, mips_queries
from repro.launch.mesh import make_mesh_compat

SEED = {seed}
N, D, P, K, EF = 510, 16, 4, 10, 32   # ragged tail: Nloc=128, count[3]=126
mesh = make_mesh_compat((P,), ("model",))
kw = dict(partition="norm_bands", storage="int8",   # stores cover f32 too
          build_backend="scan", max_degree=8, ef_construction=16,
          insert_batch=64)

def recall(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / K
                    for a, b in zip(np.asarray(ids), gt)])

for profile in {profiles}:
    items = jnp.asarray(mips_dataset(N, D, profile=profile, seed=SEED % 997))
    queries = jnp.asarray(mips_queries(16, D, seed=SEED % 991 + 1))
    gt = np.argsort(-(np.asarray(items) @ np.asarray(queries).T), axis=0)[:K].T
    for plus in (False, True):
        idx = build_sharded(items, P, plus=plus, **kw)
        for storage in ("f32", "int8"):
            for backend in ("reference", "pallas"):
                tag = (profile, "ipnsw+" if plus else "ipnsw", storage, backend)
                common = dict(k=K, ef=EF, plus=plus, backend=backend,
                              storage=storage)
                ids_o, sc_o, ev_o = sharded_search_reference(idx, queries, **common)
                ids_d, sc_d, ev_d = sharded_search(idx, queries, mesh=mesh, **common)
                assert np.array_equal(np.asarray(ids_o), np.asarray(ids_d)), tag
                # ids bit-identical; scores to fp tolerance (shard_map and
                # vmap contract the same dots in different orders, same as
                # the seed pin in test_distributed.py)
                assert np.allclose(np.asarray(sc_o), np.asarray(sc_d)), tag
                base = recall(ids_o, gt)
                for driver, kwargs in (
                    (sharded_search_reference, {{}}),
                    (sharded_search, {{"mesh": mesh}}),
                ):
                    ids_r, sc_r, ev_r = driver(
                        idx, queries, route="upper_bound", **kwargs, **common)
                    got = recall(ids_r, gt)
                    assert got >= base - 0.01, (tag, driver.__name__, got, base)
                    assert np.asarray(ids_r).max() < N
print("OK")
"""
    )


# ---------------------------------------------------------------------------
# 3. the skip rule, pinned as a unit
# ---------------------------------------------------------------------------


def test_shard_visit_mask_skips_iff_bound_below_kth():
    """skip IFF max_norm_s * ||q|| < kth_score; a tie still visits."""
    mn, qn = jnp.float32(2.0), jnp.float32(3.0)
    bound = float(mn * qn)
    assert bool(shard_visit_mask(mn, qn, jnp.float32(bound - 1e-3)))
    assert bool(shard_visit_mask(mn, qn, jnp.float32(bound)))       # tie
    assert not bool(shard_visit_mask(mn, qn, jnp.float32(bound + 1e-3)))
    # vectorized over queries
    kth = jnp.asarray([0.0, bound, bound + 1.0, -jnp.inf], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(shard_visit_mask(mn, jnp.full((4,), qn), kth)),
        [True, True, False, True],
    )


def test_routing_skips_provably_unable_shard_only(rng):
    """Crafted two-band catalog: a query aligned with the hot band's items
    must skip the cold band (bound < kth), a query orthogonal to the hot
    band must visit it (hot scores ~0 leave kth below the cold bound) —
    and in both cases routed results equal the exhaustive merge."""
    d, k = 4, 2
    hot = np.zeros((8, d), np.float32)
    hot[:, 0] = 10.0 + np.arange(8)              # norms 10..17, direction e0
    cold = np.zeros((8, d), np.float32)
    cold[:, 1] = 1.0                              # norm 1, direction e1
    items = jnp.asarray(np.concatenate([hot, cold]))
    idx = build_sharded(items, 2, plus=False, partition="norm_bands",
                        max_degree=4, ef_construction=8, insert_batch=8)
    assert float(idx.max_norm[0]) == 17.0 and float(idx.max_norm[1]) == 1.0

    q = np.zeros((2, d), np.float32)
    q[0, 0] = 1.0   # aligned with hot: kth >= 10 > bound_cold = 1 -> skip
    q[1, 1] = 1.0   # orthogonal to hot: kth ~ 0 < bound_cold = 1 -> visit
    common = dict(k=k, ef=8, plus=False)
    ids_u, sc_u, _ = sharded_search_reference(idx, jnp.asarray(q), **common)
    ids_r, sc_r, _, st = sharded_search_reference(
        idx, jnp.asarray(q), route="upper_bound", return_stats=True, **common)
    assert isinstance(st, RouteStats)
    np.testing.assert_array_equal(np.asarray(st.shards_visited), [1, 2])
    np.testing.assert_array_equal(np.asarray(st.bound_skips), [1, 0])
    np.testing.assert_array_equal(np.asarray(ids_r), np.asarray(ids_u))
    np.testing.assert_array_equal(np.asarray(sc_r), np.asarray(sc_u))
    # the orthogonal query's answers really come from the cold band
    assert set(np.asarray(ids_r)[1].tolist()) <= set(range(8, 16))


def test_banded_all_negative_query_never_surfaces_pad_ids(rng):
    """PR-2 regression, banded + routed edition: every real score negative,
    N not divisible by P (zero-pad tail rows score 0.0 and would win any
    merge that forgets the count mask), routing enabled."""
    n, d, p = 101, 8, 4
    items = jnp.asarray(-np.abs(rng.normal(size=(n, d))).astype(np.float32))
    queries = jnp.asarray(np.abs(rng.normal(size=(6, d))).astype(np.float32))
    idx = build_sharded(items, p, plus=False, partition="norm_bands",
                        max_degree=8, ef_construction=16, insert_batch=32)
    for route in ("none", "upper_bound"):
        ids, scores, _ = sharded_search_reference(
            idx, queries, k=5, ef=16, plus=False, route=route)
        ids, scores = np.asarray(ids), np.asarray(scores)
        assert ids.max() < n, (route, ids.max())
        assert (ids >= 0).all(), route
        assert float(scores.max()) < 0.0, route


# ---------------------------------------------------------------------------
# composition: tiering rides the routed path
# ---------------------------------------------------------------------------


def test_tiered_storage_matches_f32_recall(rng):
    """storage="tiered" (hot band f32, cold bands int8) keeps routed
    recall@10 within 0.01 of the all-f32 routed run — the int8 walks end in
    an exact fp32 rerank, so only walk ORDER can differ."""
    n, d, p = 400, 16, 4
    items = jnp.asarray(mips_dataset(n, d, profile="lognormal",
                                     seed=int(rng.integers(0, 2**31)) % 997))
    queries = jnp.asarray(mips_queries(16, d, seed=3))
    idx = build_sharded(items, p, plus=False, partition="norm_bands",
                        storage="tiered", max_degree=8, ef_construction=16,
                        insert_batch=64)
    gt = _exact_topk(items, queries)
    common = dict(k=10, ef=32, plus=False, route="upper_bound")
    ids_f, _, _ = sharded_search_reference(idx, queries, storage="f32", **common)
    ids_t, _, _ = sharded_search_reference(
        idx, queries, storage="tiered", **common)
    assert _recall(ids_t, gt) >= _recall(ids_f, gt) - 0.01


def test_route_requires_max_norm():
    """Legacy indexes (no max_norm recorded) must fail loudly, not skip
    arbitrarily."""
    items = jnp.asarray(np.eye(8, 4, dtype=np.float32))
    idx = build_sharded(items, 2, plus=False, max_degree=4,
                        ef_construction=8, insert_batch=8)
    legacy = idx._replace(max_norm=None)
    with pytest.raises(ValueError, match="max_norm"):
        sharded_search_reference(legacy, items[:2], route="upper_bound")
