"""The int8 storage backend (DESIGN.md §8): quantizer bounds, walk-backend
parity on the quantized store, the storage knob across every index class and
the sharded path, and the acceptance floor — end-to-end recall@10 with
``storage="int8"`` + exact fp32 rerank within 0.01 of ``storage="f32"`` on
both of the paper's norm regimes (tight gaussian / heavy-tailed lognormal).
"""
import functools

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    IpNSW,
    IpNSWPlus,
    STORAGE_BACKENDS,
    dequantize,
    exact_topk,
    make_store,
    quantize_items,
    recall_at_k,
)
from repro.core.search import beam_search
from repro.data import mips_dataset, mips_queries

N, D, K, EF = 1200, 24, 10, 48
PROFILES = ("gaussian", "lognormal")
# int8 + exact rerank must track f32 within this on the same query batch
# (the ISSUE-4 acceptance criterion).
MAX_RECALL_DELTA = 0.01


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


def test_storage_backends_tuple():
    assert STORAGE_BACKENDS == ("f32", "int8")


def test_quantize_roundtrip_error_bound(rng):
    """Per-element reconstruction error is bounded by scale/2 — the
    symmetric-rounding contract, including across extreme per-row norms."""
    x = rng.normal(size=(100, 33)).astype(np.float32)
    x *= np.geomspace(1e-5, 1e5, 100).astype(np.float32)[:, None]
    store = quantize_items(jnp.asarray(x))
    assert store.codes.dtype == jnp.int8
    assert store.scales.shape == (100,)
    err = np.abs(np.asarray(dequantize(store)) - x)
    bound = np.asarray(store.scales)[:, None] * 0.5 + 1e-30
    assert np.all(err <= bound * (1 + 1e-5))


def test_quantize_zero_rows_score_zero(rng):
    """All-zero rows (the distributed tail-shard padding) must quantize to
    all-zero codes — their quantized scores stay exactly 0.0."""
    x = np.zeros((4, 8), np.float32)
    x[0] = rng.normal(size=8)
    store = quantize_items(jnp.asarray(x))
    codes = np.asarray(store.codes)
    assert np.all(codes[1:] == 0)
    assert np.all(np.isfinite(np.asarray(store.scales)))


def test_make_store_resolves_knob(rng):
    x = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    assert make_store(x, "f32") is None
    st = make_store(x, "int8")
    assert st is not None and st.codes.shape == (10, 4)
    with pytest.raises(ValueError, match="storage"):
        make_store(x, "fp16")


# ---------------------------------------------------------------------------
# beam_search: knob validation, backend parity on the quantized store
# ---------------------------------------------------------------------------


def _graph(rng, n=300, d=24, md=8):
    from repro.core.build import build_graph

    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return build_graph(items, max_degree=md, ef_construction=16, insert_batch=64)


def test_beam_search_rejects_unknown_storage(rng):
    g = _graph(rng)
    q = jnp.asarray(rng.normal(size=(2, 24)).astype(np.float32))
    init = jnp.zeros((2, 1), jnp.int32)
    with pytest.raises(ValueError, match="storage"):
        beam_search(g, q, init, pool_size=8, max_steps=4, k=2, storage="fp16")


def test_int8_rejects_custom_score_fn(rng):
    g = _graph(rng)
    q = jnp.asarray(rng.normal(size=(2, 24)).astype(np.float32))
    init = jnp.zeros((2, 1), jnp.int32)
    with pytest.raises(ValueError, match="score_fn"):
        beam_search(g, q, init, pool_size=8, max_steps=4, k=2,
                    storage="int8", score_fn=lambda q, x, i: q[:, :1] * 0)


def test_int8_walk_backend_parity(rng):
    """reference and pallas int8 walks return identical ids/evals/visited —
    the same bit-parity contract the f32 backends carry (DESIGN.md §3)."""
    g = _graph(rng)
    q = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (5, 1)).astype(jnp.int32)
    kw = dict(pool_size=16, max_steps=32, k=5, storage="int8")
    r1 = beam_search(g, q, init, backend="reference", **kw)
    r2 = beam_search(g, q, init, backend="pallas", **kw)
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    assert np.array_equal(np.asarray(r1.evals), np.asarray(r2.evals))
    assert np.array_equal(np.asarray(r1.visited), np.asarray(r2.visited))
    np.testing.assert_allclose(
        np.asarray(r1.scores), np.asarray(r2.scores), rtol=1e-5, atol=1e-5
    )


def test_int8_rerank_scores_are_exact_fp32(rng):
    """Returned scores after the rerank are the EXACT inner products of the
    returned ids — not the quantized walk scores."""
    g = _graph(rng)
    q = jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (3, 1)).astype(jnp.int32)
    r = beam_search(g, q, init, pool_size=16, max_steps=32, k=5, storage="int8")
    ids = np.asarray(r.ids)
    items = np.asarray(g.items)
    qs = np.asarray(q)
    for b in range(3):
        for j, i in enumerate(ids[b]):
            if i >= 0:
                np.testing.assert_allclose(
                    np.asarray(r.scores)[b, j], qs[b] @ items[i], rtol=1e-5
                )


# ---------------------------------------------------------------------------
# end-to-end recall deltas (the acceptance criterion) + index classes
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _items(profile):
    return jnp.asarray(mips_dataset(N, D, profile=profile, seed=11))


@functools.lru_cache(maxsize=None)
def _ipnsw(profile):
    return IpNSW(max_degree=12, ef_construction=32, insert_batch=256).build(
        _items(profile)
    )


@functools.lru_cache(maxsize=None)
def _ipnsw_plus(profile):
    return IpNSWPlus(max_degree=12, ef_construction=32, insert_batch=256).build(
        _items(profile)
    )


def _queries(seed=5):
    return jnp.asarray(mips_queries(32, D, seed=seed))


def _gt(profile, seed=5):
    _, ids = exact_topk(_queries(seed), _items(profile), k=K)
    return np.asarray(ids)


@pytest.mark.parametrize("profile", PROFILES)
def test_ipnsw_int8_recall_within_delta(profile):
    q, gt = _queries(), _gt(profile)
    idx = _ipnsw(profile)
    r32 = recall_at_k(np.asarray(idx.search(q, k=K, ef=EF).ids), gt)
    r8 = recall_at_k(
        np.asarray(idx.search(q, k=K, ef=EF, storage="int8").ids), gt
    )
    assert r8 >= r32 - MAX_RECALL_DELTA, (profile, r32, r8)


@pytest.mark.parametrize("profile", PROFILES)
def test_ipnsw_plus_int8_recall_within_delta(profile):
    q, gt = _queries(), _gt(profile)
    idx = _ipnsw_plus(profile)
    r32 = recall_at_k(np.asarray(idx.search(q, k=K, ef=EF).ids), gt)
    r8 = recall_at_k(
        np.asarray(idx.search(q, k=K, ef=EF, storage="int8").ids), gt
    )
    assert r8 >= r32 - MAX_RECALL_DELTA, (profile, r32, r8)


def test_storage_constructor_field_matches_override():
    """Building with storage="int8" and overriding an f32 index per call land
    on the same result ids."""
    q = _queries()
    built = IpNSW(
        max_degree=12, ef_construction=32, insert_batch=256, storage="int8"
    ).build(_items("gaussian"))
    assert built.store is not None  # derived once post-build
    r_built = built.search(q, k=K, ef=EF)
    r_override = _ipnsw("gaussian").search(q, k=K, ef=EF, storage="int8")
    assert np.array_equal(np.asarray(r_built.ids), np.asarray(r_override.ids))


def test_ipnsw_rejects_unknown_storage():
    with pytest.raises(ValueError, match="storage"):
        IpNSW(storage="fp16").build(_items("gaussian"))
    with pytest.raises(ValueError, match="storage"):
        _ipnsw("gaussian").search(_queries(), k=K, ef=EF, storage="fp16")


def test_hierarchical_int8(rng):
    from repro.core import HierarchicalIpNSW

    q, gt = _queries(), _gt("lognormal")
    idx = HierarchicalIpNSW(
        max_degree=12, ef_construction=32, insert_batch=256, storage="int8"
    ).build(_items("lognormal"))
    r8 = recall_at_k(np.asarray(idx.search(q, k=K, ef=EF).ids), gt)
    r32 = recall_at_k(
        np.asarray(idx.search(q, k=K, ef=EF, storage="f32").ids), gt
    )
    assert r8 >= r32 - MAX_RECALL_DELTA, (r32, r8)


def test_sharded_int8_reference(rng):
    """Per-shard stores + count-masked merge: int8 sharded serving returns
    only real global ids and tracks the f32 sharded recall.

    N is chosen NOT to divide the shard count, so the tail shard carries
    zero-padded rows — pinning the claimed invariant that pad rows quantize
    to all-zero codes (score exactly 0.0) and stay dropped by the ``count``
    mask under int8, not just under f32."""
    from repro.core.distributed import build_sharded, sharded_search_reference

    n = N - 10  # ceil(1190/3)=397 rows/shard -> tail shard has 1 pad row
    items = _items("lognormal")[:n]
    q = _queries()
    _, gt = exact_topk(q, items, k=K)
    gt = np.asarray(gt)
    index = build_sharded(
        items, 3, plus=True, max_degree=12, ef_construction=32,
        insert_batch=256, storage="int8",
    )
    assert index.store is not None and index.ang_store is not None
    assert index.store.codes.shape[0] == 3  # stacked per-shard stores
    assert int(index.count.min()) < int(index.ip.items.shape[1])  # real pads
    ids8, sc8, _ = sharded_search_reference(
        index, q, k=K, ef=EF, plus=True, storage="int8"
    )
    ids32, _, _ = sharded_search_reference(index, q, k=K, ef=EF, plus=True)
    ids8 = np.asarray(ids8)
    assert ids8.max() < n and ids8.min() >= -1  # count mask drops pad nodes
    r8 = recall_at_k(ids8, gt)
    r32 = recall_at_k(np.asarray(ids32), gt)
    assert r8 >= r32 - MAX_RECALL_DELTA, (r32, r8)

    # An f32-built index searched with int8: the driver derives the missing
    # stores once (outside the per-shard body) and lands on the same ids.
    index_f32 = index._replace(store=None, ang_store=None)
    ids8b, _, _ = sharded_search_reference(
        index_f32, q, k=K, ef=EF, plus=True, storage="int8"
    )
    assert np.array_equal(ids8, np.asarray(ids8b))
