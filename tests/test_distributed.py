"""Multi-device semantics, via subprocesses with forced host devices (the
main test process keeps 1 device).  Each subprocess asserts agreement between
the shard_map path and its single-device oracle.

Subprocess scripts take their seeds from the function-scoped ``rng`` fixture
(conftest.py) via the ``__SEED__`` placeholder — deterministic per test, no
hardcoded generator state shared between scripts.  The model-parallel cases
(MoE / GNN / compressed allreduce / LM train step) are ``slow``: they pin
layers far from the MIPS core, so REPRO_TEST_QUICK=1 skips them.
"""
import os
import subprocess
import sys

import pytest

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

slow_multihost = pytest.mark.skipif(
    QUICK, reason="multi-host model case, skipped under REPRO_TEST_QUICK"
)


def _run(code: str, devices: int = 8, rng=None):
    if rng is not None:
        code = code.replace("__SEED__", str(int(rng.integers(0, 2**31))))
    assert "__SEED__" not in code, "script needs rng= for its seed"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_mips_search_matches_reference(rng):
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import build_sharded, sharded_search, sharded_search_reference
rng = np.random.default_rng(__SEED__)
items = jnp.asarray(rng.normal(size=(2048, 16)).astype(np.float32))
queries = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
idx = build_sharded(items, 8, plus=True, max_degree=8, ef_construction=16, insert_batch=256)
ids_ref, sc_ref, ev_ref = sharded_search_reference(idx, queries, k=5, ef=16, plus=True)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("model",))
ids_sm, sc_sm, ev_sm = sharded_search(idx, queries, mesh=mesh, k=5, ef=16, plus=True)
assert np.array_equal(np.asarray(ids_ref), np.asarray(ids_sm))
assert np.allclose(np.asarray(sc_ref), np.asarray(sc_sm))
# degraded serving keeps availability
mask = np.ones(8, bool); mask[2] = False
ids_dg, _, _ = sharded_search(idx, queries, mesh=mesh, k=5, ef=16, plus=True, shard_mask=jnp.asarray(mask))
assert np.asarray(ids_dg).shape == (8, 5)
print("OK")
""",
        rng=rng,
    )


def test_sharded_pallas_backend_and_pad_mask(rng):
    """The PR-1 fused walk kernel must be reachable from the sharded path
    (backend="pallas" returns ids identical to reference), the scan shard
    build must match the host shard build bit-for-bit, and pad nodes of the
    ragged tail shard must never surface — even when every genuine score is
    negative (a pad node's 0.0 would otherwise win the merge)."""
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import build_sharded, sharded_search, sharded_search_reference
rng = np.random.default_rng(__SEED__)
# all-negative inner products + N not divisible by 8 => zero-pad tail shard
N = 1010
items = jnp.asarray(-np.abs(rng.normal(size=(N, 16))).astype(np.float32))
queries = jnp.asarray(np.abs(rng.normal(size=(8, 16))).astype(np.float32))
# insert_batch < Nloc=127 so the vmapped lax.scan body actually runs
# (a larger batch would build every shard entirely in the bootstrap step)
kw = dict(plus=True, max_degree=8, ef_construction=16, insert_batch=64)
idx = build_sharded(items, 8, build_backend="scan", **kw)
idx_host = build_sharded(items, 8, build_backend="host", **kw)
assert np.array_equal(np.asarray(idx.ip.adj), np.asarray(idx_host.ip.adj))
assert np.array_equal(np.asarray(idx.ang.adj), np.asarray(idx_host.ang.adj))
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("model",))
# ang_ef/k_angular now reach the local walks (built with defaults 10/10;
# searched with the build-time values passed explicitly)
common = dict(k=5, ef=16, plus=True, ang_ef=10, k_angular=10)
ids_ref, sc_ref, ev_ref = sharded_search(idx, queries, mesh=mesh, backend="reference", **common)
ids_pal, sc_pal, ev_pal = sharded_search(idx, queries, mesh=mesh, backend="pallas", **common)
assert np.array_equal(np.asarray(ids_ref), np.asarray(ids_pal))
assert np.allclose(np.asarray(sc_ref), np.asarray(sc_pal))
ids_o, _, _ = sharded_search_reference(idx, queries, backend="pallas", **common)
assert np.array_equal(np.asarray(ids_ref), np.asarray(ids_o))
# pad-node regression: no id >= N, no dropped rows
for ids in (ids_ref, ids_pal):
    ids = np.asarray(ids)
    assert ids.max() < N, ids.max()
    assert (ids >= 0).all()
# adversarial merge ordering: every score must be strictly negative
assert float(np.asarray(sc_ref).max()) < 0.0
print("OK")
""",
        rng=rng,
    )


@pytest.mark.slow
@slow_multihost
def test_moe_sharded_matches_local(rng):
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.models import moe as M
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
d, f, E = 16, 32, 8
params, _ = M.moe_init(jax.random.PRNGKey(__SEED__ % 2**31), d, f, E, jnp.float32)
x = jnp.asarray(np.random.default_rng(__SEED__).normal(size=(4, 8, d)).astype(np.float32))
# big capacity => no drops => sharded == local exactly
o_local, aux_l = M.moe_apply(params, x, n_experts=E, top_k=2, capacity_factor=16.0)
o_shard, aux_s = M.moe_apply(params, x, n_experts=E, top_k=2, capacity_factor=16.0, mesh=mesh)
# token outputs agree exactly; the aux load-balance loss is computed per
# data shard (mean of per-shard E[me*ce] != global E[me*ce]) — standard for
# dp-sharded MoE aux, so only loosely compared.
assert np.allclose(np.asarray(o_local), np.asarray(o_shard), rtol=1e-4, atol=1e-5), np.abs(np.asarray(o_local)-np.asarray(o_shard)).max()
assert abs(float(aux_l) - float(aux_s)) < 0.15 * abs(float(aux_l))
print("OK")
""",
        rng=rng,
    )


@pytest.mark.slow
@slow_multihost
def test_gnn_sharded_matches_local(rng):
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.models import gnn as G
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
cfg = G.GNNConfig(n_layers=2, d_hidden=16, d_feat=8, d_edge=4, remat=False)
params, _ = G.init(jax.random.PRNGKey(__SEED__ % 2**31), cfg)
rng = np.random.default_rng(__SEED__)
N, E = 64, 128  # divisible by 8 devices
graph = dict(
    node_feat=jnp.asarray(rng.normal(size=(N, 8)).astype(np.float32)),
    edge_feat=jnp.asarray(rng.normal(size=(E, 4)).astype(np.float32)),
    src=jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
    dst=jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
    targets=jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32)),
)
out_local = G.forward(params, graph, cfg)
out_shard = G.forward(params, graph, cfg, mesh=mesh)
assert np.allclose(np.asarray(out_local), np.asarray(out_shard), rtol=1e-4, atol=1e-5)
# gradients agree too (collectives differentiate correctly)
g1 = jax.grad(G.mse_loss)(params, graph, cfg)
g2 = jax.grad(lambda p: G.mse_loss(p, graph, cfg, mesh=mesh))(params)
d1 = jax.tree.leaves(g1)[0]; d2 = jax.tree.leaves(g2)[0]
assert np.allclose(np.asarray(d1), np.asarray(d2), rtol=1e-3, atol=1e-5)
print("OK")
""",
        rng=rng,
    )


@pytest.mark.slow
@slow_multihost
def test_compressed_allreduce_error_feedback(rng):
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.train.compress import make_compressed_allreduce
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("data",))
f = make_compressed_allreduce(mesh, ("data",))
rng = np.random.default_rng(__SEED__)
x = jnp.asarray(rng.normal(size=(8, 512)).astype(np.float32))
e = jnp.zeros_like(x)
exact = jnp.mean(x, axis=0)
m1, e1 = f(x, e)
err1 = float(jnp.max(jnp.abs(m1[0] - exact)))
tot = jnp.zeros_like(exact); ecur = jnp.zeros_like(x)
for _ in range(20):
    m, ecur = f(x, ecur)
    tot = tot + m[0]
err20 = float(jnp.max(jnp.abs(tot / 20 - exact)))
assert err20 < err1 * 0.5, (err1, err20)
print("OK")
""",
        rng=rng,
    )


@pytest.mark.slow
@slow_multihost
def test_lm_train_step_sharded_2x2(rng):
    """Tiny LM train step under jit with 2x2 mesh NamedShardings — the same
    wiring the production dry-run uses, on real (forced) devices."""
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import dataclasses
from repro.models import transformer as tf, layers as L
from repro.train import adamw_init, adamw_update

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 2), ("data", "model"))
L.set_batch_axes_for_mesh(mesh)
cfg = tf.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv=2,
    head_dim=8, d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=8, remat=False,
    moe_experts=4, moe_top_k=2)
params, specs = tf.init(jax.random.PRNGKey(0), cfg)
ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                               is_leaf=lambda x: isinstance(x, P))
params = jax.device_put(params, ns(specs))
opt = adamw_init(params)
toks = jnp.asarray(np.random.default_rng(__SEED__).integers(0, 64, (4, 16)).astype(np.int32))
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

def train_step(params, opt, batch):
    loss, grads = jax.value_and_grad(tf.lm_loss)(params, batch, cfg, mesh)
    return adamw_update(grads, opt, params, lr=1e-3) + (loss,)

with mesh:
    p2, o2, loss = jax.jit(train_step)(params, opt, batch)
assert np.isfinite(float(loss))
# compare against single-device result (tolerance covers the per-shard MoE
# aux-loss statistic, weight 0.01 — see test_moe_sharded_matches_local)
loss_ref = tf.lm_loss(jax.device_get(params), batch, cfg)
assert abs(float(loss) - float(loss_ref)) < 5e-3, (float(loss), float(loss_ref))
print("OK")
""",
        rng=rng,
    )
