"""Property-based recall tests: the graph walks must beat a seeded recall@10
floor vs brute-force ground truth on the paper's two norm-bias regimes —
tight Gaussian norms (Yahoo!Music/Tiny5M shape) and heavy power-law-tail
lognormal norms (WordVector/ImageNet shape, Figure 2).

Indexes are built once per profile (module cache); the property quantifies
over query seeds, so every example is a fresh query batch against the same
frozen index — the invariant the paper's Fig 7/8 curves rely on.

REPRO_TEST_QUICK=1 shrinks the example count (the index sizes and floors
stay fixed — they are the measured quantities); the four floor sweeps carry
``@pytest.mark.slow``.
"""
import functools
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; CI installs the real one
    from _propcheck import given, settings, st

from repro.core import IpNSW, IpNSWPlus, exact_topk, recall_at_k
from repro.data import mips_dataset, mips_queries

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

N, D, K, EF = 1500, 24, 10, 48
PROFILES = ("gaussian", "lognormal")  # tight norms / power-law norm tail
# Floors hold with margin: observed min recall across seeds is ~0.92
# (gaussian) / ~0.97 (lognormal) for both indexes at these build/search
# parameters (see DESIGN.md §5 for how to re-measure).
FLOORS = {"gaussian": 0.80, "lognormal": 0.85}
SETTINGS = dict(max_examples=2 if QUICK else 5, deadline=None)


@functools.lru_cache(maxsize=None)
def _items(profile):
    return jnp.asarray(mips_dataset(N, D, profile=profile, seed=7))


@functools.lru_cache(maxsize=None)
def _ipnsw(profile):
    return IpNSW(max_degree=12, ef_construction=32, insert_batch=256).build(
        _items(profile)
    )


@functools.lru_cache(maxsize=None)
def _ipnsw_plus(profile):
    return IpNSWPlus(max_degree=12, ef_construction=32, insert_batch=256).build(
        _items(profile)
    )


def _queries(seed):
    return jnp.asarray(mips_queries(32, D, seed=seed))


def _gt(profile, seed):
    _, ids = exact_topk(_queries(seed), _items(profile), k=K)
    return np.asarray(ids)


@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_beam_search_recall_floor_gaussian(seed):
    q = _queries(seed)
    r = _ipnsw("gaussian").search(q, k=K, ef=EF)
    assert recall_at_k(np.asarray(r.ids), _gt("gaussian", seed)) >= FLOORS["gaussian"]


@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_beam_search_recall_floor_lognormal(seed):
    q = _queries(seed)
    r = _ipnsw("lognormal").search(q, k=K, ef=EF)
    assert recall_at_k(np.asarray(r.ids), _gt("lognormal", seed)) >= FLOORS["lognormal"]


@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_ipnsw_plus_recall_floor_gaussian(seed):
    q = _queries(seed)
    r = _ipnsw_plus("gaussian").search(q, k=K, ef=EF)
    assert recall_at_k(np.asarray(r.ids), _gt("gaussian", seed)) >= FLOORS["gaussian"]


@pytest.mark.slow
@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_ipnsw_plus_recall_floor_lognormal(seed):
    q = _queries(seed)
    r = _ipnsw_plus("lognormal").search(q, k=K, ef=EF)
    assert recall_at_k(np.asarray(r.ids), _gt("lognormal", seed)) >= FLOORS["lognormal"]


@pytest.mark.parametrize("profile", PROFILES)
def test_served_traffic_recall_matches_direct_floor(profile):
    """Served-traffic recall floor: a short virtual-time Poisson trace
    through the continuous-batching loop (launch/serve_loop.py) must match
    the direct ``beam_search`` floor at the same ef bucket.  Deadlines are
    generous so every request is served at its requested ef; padding
    equivalence then makes the served ids identical to the one-shot batch
    search, so the serving layer can never cost recall."""
    from repro.launch.serve_loop import (
        BucketLadder, LinearServiceModel, ServeLoop, VirtualClock,
        poisson_trace,
    )

    idx = _ipnsw(profile)
    q = _queries(202)
    gt = _gt(profile, 202)
    trace = poisson_trace(
        np.asarray(q), rate_qps=2000.0, seed=9, ef=EF,
        classes=("relaxed",), budgets={"relaxed": 60.0},
    )
    loop = ServeLoop(
        idx, ladder=BucketLadder(batches=(8, 32), efs=(EF // 2, EF)),
        clock=VirtualClock(), k=K, service_model=LinearServiceModel(),
    )
    stats = loop.run(trace)
    assert len(stats.responses) == q.shape[0]
    assert all(r.ef_served == EF for r in stats.responses)
    served_ids = np.stack(
        [r.ids for r in sorted(stats.responses, key=lambda r: r.rid)]
    )
    direct = idx.search(q, k=K, ef=EF)
    assert np.array_equal(served_ids, np.asarray(direct.ids))
    assert recall_at_k(served_ids, gt) >= FLOORS[profile]
    assert stats.recompiles_steady == 0


def test_pallas_backend_recall_identical():
    """The fused backend changes speed, never results: same recall, same ids."""
    q = _queries(123)
    idx = _ipnsw("gaussian")
    r_ref = idx.search(q, k=K, ef=EF)
    r_pal = idx.search(q, k=K, ef=EF, backend="pallas")
    assert np.array_equal(np.asarray(r_ref.ids), np.asarray(r_pal.ids))
