"""Training substrate: optimizer math, checkpoint commit protocol, elastic
restore, preemption-safe loop resume, data determinism."""
import os
import shutil
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import SyntheticClickStream, SyntheticLMStream
from repro.train import adamw_init, adamw_update, checkpoint as ckpt, cosine_schedule, loop


def test_adamw_converges_least_squares(rng):
    A = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    w_true = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    y = A @ w_true
    params = {"w": jnp.zeros((8,), jnp.float32)}
    opt = adamw_init(params)
    loss_fn = lambda p: jnp.mean((A @ p["w"] - y) ** 2)
    for _ in range(300):
        l, g = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(g, opt, params, lr=3e-2, weight_decay=0.0)
    assert float(l) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    opt = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9, jnp.float32)}
    p2, _ = adamw_update(huge, opt, params, lr=1.0, clip=1.0, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.int32(0), base_lr=1.0, warmup=10, total=100)
    assert float(s) == 0.0
    mid = cosine_schedule(jnp.int32(10), base_lr=1.0, warmup=10, total=100)
    assert abs(float(mid) - 1.0) < 1e-6
    end = cosine_schedule(jnp.int32(100), base_lr=1.0, warmup=10, total=100)
    assert float(end) <= 0.11


def test_checkpoint_roundtrip_and_commit(tmp_path, rng):
    tree = {
        "a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
        "b": {"c": jnp.arange(5, dtype=jnp.int32)},
    }
    d = str(tmp_path)
    ckpt.save(d, 3, tree)
    assert ckpt.latest_step(d) == 3
    template = jax.eval_shape(lambda: tree)
    out, manifest = ckpt.restore(d, template)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_crash_leaves_no_partial(tmp_path, rng):
    """A *.tmp directory (simulated crashed writer) is ignored by restore."""
    tree = {"a": jnp.ones((2,), jnp.float32)}
    d = str(tmp_path)
    ckpt.save(d, 1, tree)
    # simulate a crashed later save
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1
    out, _ = ckpt.restore(d, jax.eval_shape(lambda: tree))
    assert float(out["a"][0]) == 1.0


def test_checkpoint_latest_pointer_ahead_of_commit(tmp_path):
    """LATEST pointing at a missing step dir is treated as absent."""
    tree = {"a": jnp.ones((2,), jnp.float32)}
    d = str(tmp_path)
    ckpt.save(d, 1, tree)
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("99")
    assert ckpt.latest_step(d) is None


def test_loop_resume_is_deterministic(tmp_path):
    """Run 10 steps; kill; resume from ckpt at 5 and confirm identical final
    state (preemption safety + deterministic data pipeline)."""
    params0 = {"w": jnp.zeros((3,), jnp.float32)}

    def make_step():
        @jax.jit
        def step(state, batch):
            g = {"w": jnp.asarray(batch["tokens"][0, :3], jnp.float32) * 1e-3}
            p, o = adamw_update(g, state["opt"], state["params"], lr=1e-2)
            return {"params": p, "opt": o}, {"loss": jnp.sum(p["w"])}

        return step

    stream = SyntheticLMStream(vocab=100, batch=2, seq=8, seed=7)
    d1 = str(tmp_path / "run1")
    state0 = {"params": params0, "opt": adamw_init(params0)}
    res_full = loop.run(
        make_step(), state0, stream, n_steps=10, ckpt_dir=d1, ckpt_every=5, verbose=False
    )

    # second run: fresh process state, resumes from the step-10 checkpoint,
    # then a third run from scratch in a new dir but interrupted at 5
    d2 = str(tmp_path / "run2")
    res_a = loop.run(
        make_step(), state0, stream, n_steps=5, ckpt_dir=d2, ckpt_every=5, verbose=False
    )
    res_b = loop.run(
        make_step(), state0, stream, n_steps=10, ckpt_dir=d2, ckpt_every=5, verbose=False
    )
    np.testing.assert_allclose(
        np.asarray(res_full.state["params"]["w"]),
        np.asarray(res_b.state["params"]["w"]),
        rtol=1e-6,
    )


def test_stream_determinism():
    s1 = SyntheticLMStream(vocab=50, batch=2, seq=4, seed=3)
    s2 = SyntheticLMStream(vocab=50, batch=2, seq=4, seed=3)
    np.testing.assert_array_equal(s1.batch_at(17)["tokens"], s2.batch_at(17)["tokens"])
    c1 = SyntheticClickStream(n_items=100, batch=2, seq=5, seed=3)
    np.testing.assert_array_equal(
        c1.batch_at(4)["hist"],
        SyntheticClickStream(n_items=100, batch=2, seq=5, seed=3).batch_at(4)["hist"],
    )
