"""NormFilteredIndex: the beyond-paper norm-filter wrapper (see
benchmarks/beyond_paper.py for the measured keep_frac trade-off).

Pinned here: the local->global id mapping back to the full catalog, the
16-item keep_frac floor, composition with both inner index classes
(plus=True/False) and with the int8 storage backend.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import IpNSW, IpNSWPlus, NormFilteredIndex, exact_topk, recall_at_k
from repro.data import mips_dataset, mips_queries

N, D, K = 1000, 16, 10


def _items():
    return jnp.asarray(mips_dataset(N, D, profile="lognormal", seed=3))


def _queries():
    return jnp.asarray(mips_queries(16, D, seed=9))


@pytest.mark.parametrize("plus", [True, False])
def test_global_id_mapping_and_inner_class(plus):
    items = _items()
    nf = NormFilteredIndex(
        keep_frac=0.5, plus=plus, max_degree=8, ef_construction=24,
        insert_batch=128,
    ).build(items)
    assert isinstance(nf.inner, IpNSWPlus if plus else IpNSW)
    kept = set(int(i) for i in nf.global_ids)
    assert len(kept) == N // 2

    res = nf.search(_queries(), k=K, ef=32)
    ids = np.asarray(res.ids)
    # every returned id is a global id of the kept slice (or -1 padding)
    assert set(ids[ids >= 0].ravel()) <= kept
    assert ids.max() < N

    # the mapping is FULL-catalog correct: the returned scores must equal
    # the inner products of the mapped global rows
    scores = np.asarray(res.scores)
    full = np.asarray(items)
    qs = np.asarray(_queries())
    b, j = 0, int(np.argmax(ids[0] >= 0))
    np.testing.assert_allclose(
        scores[b, j], qs[b] @ full[ids[b, j]], rtol=1e-5
    )


def test_keeps_largest_norm_items():
    """The filter keeps exactly the top-keep_frac rows by norm, so a query
    aligned with the largest-norm item must get it back as top-1 under its
    GLOBAL id."""
    rng = np.random.default_rng(0)
    items = rng.normal(size=(400, D)).astype(np.float32)
    hub = 137
    items[hub] *= 50.0  # overwhelming norm -> top-1 for almost any query
    nf = NormFilteredIndex(
        keep_frac=0.25, plus=False, max_degree=8, ef_construction=24,
        insert_batch=128,
    ).build(jnp.asarray(items))
    assert hub in set(int(i) for i in nf.global_ids)
    q = jnp.asarray(items[hub][None, :] / 50.0)
    res = nf.search(q, k=1, ef=32)
    assert int(np.asarray(res.ids)[0, 0]) == hub


def test_keep_frac_floor_of_16():
    items = _items()[:64]
    nf = NormFilteredIndex(
        keep_frac=0.01, plus=False, max_degree=4, ef_construction=16,
        insert_batch=64,
    ).build(items)
    assert len(nf.global_ids) == 16  # floor, not 64 * 0.01
    res = nf.search(_queries(), k=4, ef=16)
    ids = np.asarray(res.ids)
    assert set(ids[ids >= 0].ravel()) <= set(int(i) for i in nf.global_ids)


def test_recall_vs_achievable_on_kept_slice():
    """The filtered index should nearly achieve the recall ceiling imposed by
    its kept slice (the Figure-1 occupancy argument): compare against ground
    truth restricted to kept items, not the full catalog."""
    items = _items()
    nf = NormFilteredIndex(
        keep_frac=0.5, plus=True, max_degree=12, ef_construction=32,
        insert_batch=128,
    ).build(items)
    kept = np.asarray(nf.global_ids)
    sub = jnp.asarray(np.asarray(items)[np.sort(kept)])
    _, gt_local = exact_topk(_queries(), sub, k=K)
    gt_global = np.sort(kept)[np.asarray(gt_local)]
    res = nf.search(_queries(), k=K, ef=48)
    assert recall_at_k(np.asarray(res.ids), gt_global) >= 0.85


def test_composes_with_int8_storage():
    items = _items()
    nf32 = NormFilteredIndex(
        keep_frac=0.5, plus=False, max_degree=12, ef_construction=32,
        insert_batch=128,
    ).build(items)
    nf8 = NormFilteredIndex(
        keep_frac=0.5, plus=False, max_degree=12, ef_construction=32,
        insert_batch=128, storage="int8",
    ).build(items)
    assert nf8.inner.store is not None
    _, gt = exact_topk(_queries(), items, k=K)
    r32 = recall_at_k(np.asarray(nf32.search(_queries(), k=K, ef=48).ids), np.asarray(gt))
    r8 = recall_at_k(np.asarray(nf8.search(_queries(), k=K, ef=48).ids), np.asarray(gt))
    assert r8 >= r32 - 0.01, (r32, r8)
