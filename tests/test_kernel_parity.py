"""Kernel parity matrix: every kernels/*/ops.py vs its ref.py oracle, in
interpret mode, across shapes, odd (non-128-multiple) dims, and -1 padded
ids — including the fused beam_step kernel (bit-exact ids vs the reference
step and vs the reference full walk) and the fused commit_merge kernel
(bit-exact adjacency vs the segmented top-M reference merge)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.beam_step import beam_step, beam_step_ref
from repro.kernels.commit_merge import (
    DEFAULT_COMMIT_TILE,
    commit_merge,
    commit_merge_ref,
    resolve_commit_tile,
)
from repro.kernels.gather_score import gather_score, gather_score_ref
from repro.kernels.mips_topk import mips_topk, mips_topk_ref
from repro.kernels.quant_score import quant_score, quant_score_ref
from repro.kernels.topk_merge import topk_merge, topk_merge_ref
from repro.core.storage import quantize_items


# ---------------------------------------------------------------------------
# gather_score / mips_topk / topk_merge: odd dims + -1 padded ids
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,n,d,w",
    [(1, 40, 1, 1), (3, 100, 17, 5), (8, 333, 129, 9), (16, 512, 127, 16)],
)
def test_gather_score_odd_dims_and_padded_ids(rng, b, n, d, w):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = rng.integers(0, n, size=(b, w)).astype(np.int32)
    ids[rng.random(size=ids.shape) < 0.3] = -1  # -1 padding slots
    s = gather_score(q, x, jnp.asarray(ids))
    # oracle contract: ids pre-clamped (kernel scores -1 against row 0)
    r = gather_score_ref(q, x, jnp.asarray(np.maximum(ids, 0)))
    np.testing.assert_allclose(np.asarray(s), np.asarray(r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,n,d,k", [(2, 130, 31, 3), (5, 999, 65, 7)])
def test_mips_topk_odd_dims(rng, b, n, d, k):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    vs, ids = mips_topk(q, x, k=k)
    rvs, rids = mips_topk_ref(q, x, k=k)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(rvs), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


@pytest.mark.parametrize("b,l,m", [(1, 1, 1), (3, 7, 5), (17, 33, 9)])
def test_topk_merge_odd_shapes_and_padded_ids(rng, b, l, m):
    pool_s = rng.normal(size=(b, l)).astype(np.float32)
    pool_i = rng.integers(-1, 100, (b, l)).astype(np.int32)
    pool_s[pool_i < 0] = -np.inf  # -1 slots carry -inf, like a real pool
    new_s = rng.normal(size=(b, m)).astype(np.float32)
    new_i = rng.integers(-1, 100, (b, m)).astype(np.int32)
    new_s[new_i < 0] = -np.inf
    args = (
        pool_s, pool_i, rng.integers(0, 2, (b, l)).astype(np.int32),
        new_s, new_i, rng.integers(0, 2, (b, m)).astype(np.int32),
    )
    out = topk_merge(*map(jnp.asarray, args))
    ref = topk_merge_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    assert np.array_equal(np.asarray(out[2]), np.asarray(ref[2]))


# ---------------------------------------------------------------------------
# quant_score: the int8 storage backend's gathered scorer (DESIGN.md §8) —
# odd d, -1 padded ids, all-invalid rows, extreme per-row norms
# ---------------------------------------------------------------------------


def _quant_case(rng, b, n, d, w, norm_spread: float = 1.0):
    """Items whose per-row norms span ``norm_spread`` orders of magnitude
    either way (the lognormal hub tail per-row scales exist for)."""
    x = rng.normal(size=(n, d)).astype(np.float32)
    x *= np.geomspace(
        10.0 ** -norm_spread, 10.0 ** norm_spread, n
    ).astype(np.float32)[:, None]
    store = quantize_items(jnp.asarray(x))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ids = rng.integers(0, n, size=(b, w)).astype(np.int32)
    ids[rng.random(size=ids.shape) < 0.3] = -1  # -1 padding slots
    if b > 1:
        ids[-1] = -1  # one all-invalid row
    return q, store, jnp.asarray(ids)


@pytest.mark.parametrize(
    "b,n,d,w",
    [(1, 40, 1, 1), (3, 100, 17, 5), (8, 333, 129, 9), (16, 512, 127, 16)],
)
def test_quant_score_odd_dims_and_padded_ids(rng, b, n, d, w):
    q, store, ids = _quant_case(rng, b, n, d, w)
    out = quant_score(q, store.codes, store.scales, ids)
    ref = quant_score_ref(q, store.codes, store.scales, ids)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    # contract: -1 ids are exactly -inf on both paths
    mask = np.asarray(ids) < 0
    assert np.all(np.asarray(out)[mask] == -np.inf)
    assert np.all(np.asarray(ref)[mask] == -np.inf)
    assert np.all(np.isfinite(np.asarray(out)[~mask]))


@pytest.mark.parametrize("norm_spread", [4.0, 6.0])
def test_quant_score_extreme_per_row_norms(rng, norm_spread):
    """Per-row scales must keep huge-norm hubs and tiny-norm tail items both
    finite and relatively accurate — the reason the quantizer is per-row."""
    b, n, d, w = 4, 200, 33, 8
    q, store, ids = _quant_case(rng, b, n, d, w, norm_spread=norm_spread)
    out = quant_score(q, store.codes, store.scales, ids)
    ref = quant_score_ref(q, store.codes, store.scales, ids)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


def test_quant_score_all_invalid(rng):
    q, store, _ = _quant_case(rng, 3, 50, 8, 4)
    ids = jnp.full((3, 4), -1, jnp.int32)
    out = np.asarray(quant_score(q, store.codes, store.scales, ids))
    assert np.all(out == -np.inf)


@pytest.mark.parametrize("b,n,d,k", [(2, 130, 31, 3), (5, 999, 65, 7)])
def test_mips_topk_quantized_odd_dims(rng, b, n, d, k):
    """The int8 tile path of the exact scan vs its jnp oracle."""
    x = rng.normal(size=(n, d)).astype(np.float32)
    store = quantize_items(jnp.asarray(x))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    vs, ids = mips_topk(q, store.codes, store.scales, k=k)
    rvs, rids = mips_topk_ref(q, store.codes, k=k, scales=store.scales)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(rvs), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


# ---------------------------------------------------------------------------
# flash_attn: representative cell so the matrix covers every kernel pair
# (tile-granular kernel — block shape sweeps live in test_kernels.py)
# ---------------------------------------------------------------------------


def test_flash_attn_parity_cell(rng):
    from repro.kernels.flash_attn import flash_attention_head, flash_attention_head_ref

    q = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    out = flash_attention_head(q, k, v, bq=64, bk=64)
    ref = flash_attention_head_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# commit_merge: bit-exact adjacency parity vs the segmented top-M reference
# ---------------------------------------------------------------------------


def _assert_commit_parity(adj, items, targets, cands, scores, **kw):
    args = tuple(map(jnp.asarray, (adj, items, targets, cands, scores)))
    ref = commit_merge_ref(*args)
    out = commit_merge(*args, **kw)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize(
    "n,m,e,d",
    [
        (20, 1, 1, 1),       # degenerate everything
        (50, 4, 33, 8),      # odd E
        (100, 7, 64, 17),    # odd M, odd d
        (40, 3, 55, 129),    # odd everything, d > 128
        (200, 16, 256, 48),  # paper-scale degree
    ],
)
def test_commit_merge_matches_ref_bit_exact(rng, n, m, e, d):
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = rng.integers(-1, n, size=(e,)).astype(np.int32)  # -1 padded
    cands = rng.integers(-1, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)


def test_commit_merge_duplicate_pairs_first_proposal_wins(rng):
    """Duplicate (target, cand) pairs — even with different scores — must
    collapse to the first proposal in input order, like the reference's
    stable pass-1 sort."""
    n, m, d = 30, 4, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.array([3, 3, 3, 3, 9, 9, 9], np.int32)
    cands = np.array([5, 5, 5, 8, 2, 2, 8], np.int32)
    scores = np.array([1.0, 9.0, -2.0, 0.5, 4.0, -4.0, 0.25], np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)


def test_commit_merge_proposal_replaces_existing_edge(rng):
    """A proposal duplicating an existing edge replaces it (the proposal's
    score wins), including when that demotes the edge out of the top-M."""
    n, m, d = 30, 4, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    adj[11] = [5, 9, -1, -1]
    targets = np.array([11, 11, 11], np.int32)
    cands = np.array([5, 20, 9], np.int32)
    scores = np.array([100.0, -100.0, -50.0], np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)


def test_commit_merge_hub_target(rng):
    """The paper's hot case: every proposal lands on one large-norm hub —
    the bucket compaction must hold the whole batch for a single target."""
    n, m, e, d = 60, 4, 48, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.full((e,), 7, np.int32)
    cands = rng.integers(0, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)


def test_commit_merge_all_invalid_tail_batch(rng):
    """A fully-masked tail batch (targets all -1, the scan driver's pad
    commit) must leave the adjacency untouched."""
    n, m, e, d = 40, 3, 24, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.full((e,), -1, np.int32)
    cands = rng.integers(-1, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)
    out = commit_merge(*map(jnp.asarray, (adj, items, targets, cands, scores)))
    assert np.array_equal(np.asarray(out), adj)


def test_commit_merge_candless_target_reranks_row(rng):
    """A valid target whose proposals are all -1 still gets its row rescored
    and re-ranked from its existing edges (reference semantics)."""
    n, m, d = 40, 4, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.array([13, 13, 21, -1], np.int32)
    cands = np.array([-1, -1, -1, 5], np.int32)
    scores = np.zeros((4,), np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores)


def test_commit_merge_max_cands_exact_bound(rng):
    """max_cands equal to the true per-target distinct-cand count (the
    commit_batch contract: the insert-batch size) stays bit-exact."""
    n, m, d = 50, 4, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.full((10,), 33, np.int32)
    cands = np.arange(10, dtype=np.int32)
    scores = rng.normal(size=(10,)).astype(np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores, max_cands=10)


# ---------------------------------------------------------------------------
# commit_merge tiling: every commit_tile must reproduce the untiled reference
# bit-for-bit — the tile is grid geometry, never semantics (DESIGN.md §7)
# ---------------------------------------------------------------------------


def _hub_batch(rng, n, e, hubs):
    """A heavy-duplicate proposal table: most targets collapse onto a few
    large-norm hubs (the paper's Fig-4 in-degree skew), plus a unique tail."""
    targets = np.where(
        rng.random(e) < 0.8,
        rng.choice(hubs, size=e),
        rng.integers(0, n, size=e),
    ).astype(np.int32)
    cands = rng.integers(-1, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    return targets, cands, scores


@pytest.mark.parametrize("tile", [1, 2, 3, 5, 8, 16])
def test_commit_merge_tiled_hub_duplicates_bit_exact(rng, tile):
    """Hub-heavy batches across tile sizes, including tiles that do not
    divide the distinct-target count and tiles larger than it."""
    n, m, e, d = 60, 4, 48, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets, cands, scores = _hub_batch(rng, n, e, hubs=np.array([7, 11, 40]))
    _assert_commit_parity(adj, items, targets, cands, scores, commit_tile=tile)


@pytest.mark.parametrize("tile", [2, 4, 7])
def test_commit_merge_tile_not_dividing_distinct_count(rng, tile):
    """Exactly 5 distinct targets: every tile here leaves a partially live
    tile (5 % tile != 0), the one tile whose dead rows run clamped DMAs."""
    n, m, d = 40, 3, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.array([2, 9, 9, 17, 17, 17, 23, 31, 31, 2], np.int32)
    cands = rng.integers(0, n, size=(10,)).astype(np.int32)
    scores = rng.normal(size=(10,)).astype(np.float32)
    assert len(np.unique(targets)) == 5
    _assert_commit_parity(adj, items, targets, cands, scores, commit_tile=tile)


@pytest.mark.parametrize("tile", [1, 4, 16, 64])
def test_commit_merge_all_duplicates_single_target(rng, tile):
    """The extreme hub case: EVERY proposal lands on one target, so one tile
    row is live and every other grid step is pad — including tiles larger
    than the proposal count (clamped to E by the planner)."""
    n, m, e, d = 50, 4, 32, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.full((e,), 13, np.int32)
    cands = rng.integers(0, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    _assert_commit_parity(adj, items, targets, cands, scores, commit_tile=tile)


def test_commit_merge_tile_one_degenerates_to_untiled(rng):
    """T=1 is the pre-tiling one-target-per-step layout: same results as any
    other tile and as the reference, on a batch with pads + duplicates."""
    n, m, e, d = 50, 4, 33, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = rng.integers(-1, n, size=(e,)).astype(np.int32)
    cands = rng.integers(-1, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    args = tuple(map(jnp.asarray, (adj, items, targets, cands, scores)))
    ref = np.asarray(commit_merge_ref(*args))
    t1 = np.asarray(commit_merge(*args, commit_tile=1))
    t8 = np.asarray(commit_merge(*args, commit_tile=8))
    assert np.array_equal(ref, t1)
    assert np.array_equal(t1, t8)


def test_commit_merge_tiled_all_invalid_batch(rng):
    """A fully-masked commit stays a no-op under tiling (every grid step is
    a pad tile that must skip all DMA and write nothing)."""
    n, m, e, d = 40, 3, 24, 8
    items = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    targets = np.full((e,), -1, np.int32)
    cands = rng.integers(-1, n, size=(e,)).astype(np.int32)
    scores = rng.normal(size=(e,)).astype(np.float32)
    out = commit_merge(
        *map(jnp.asarray, (adj, items, targets, cands, scores)), commit_tile=8
    )
    assert np.array_equal(np.asarray(out), adj)


def test_resolve_commit_tile_planner():
    """The tiling planner: ints validate and clamp; "auto" climbs the
    norm-skew ladder (flat norms -> 4, gaussian-ish -> 8, heavy tail -> 16)
    and falls back to the default without data."""
    assert resolve_commit_tile(5) == 5
    assert resolve_commit_tile(5, e=3) == 3
    assert resolve_commit_tile(1000, e=4096) == 32  # MAX_COMMIT_TILE cap
    assert resolve_commit_tile("auto") == DEFAULT_COMMIT_TILE
    assert resolve_commit_tile("auto", norms=np.ones(64)) == 4
    rng = np.random.default_rng(0)
    heavy = np.exp(rng.normal(size=2000))  # lognormal, cv > 0.6
    assert resolve_commit_tile("auto", norms=heavy) == 16
    for bad in (0, -3, "quick", 2.5, True):
        with pytest.raises(ValueError, match="commit_tile"):
            resolve_commit_tile(bad)


def test_commit_batch_commit_tile_bit_exact(rng):
    """The commit_tile knob through the commit_batch dispatch seam: every
    tile commits the identical graph; invalid knobs fail eagerly."""
    from repro.core.build import commit_batch
    from repro.core.graph import empty_graph

    items = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    norms = jnp.linalg.norm(items, axis=-1)
    base = empty_graph(items, 4)
    bids = jnp.arange(32, dtype=jnp.int32)
    nbr = jnp.asarray(rng.integers(-1, 32, (32, 4)).astype(np.int32))
    sc = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    ref = commit_batch(base, bids, nbr, sc, norms)
    for tile in (1, 3, 8, "auto"):
        pal = commit_batch(
            base, bids, nbr, sc, norms, commit_backend="pallas",
            commit_tile=tile,
        )
        assert np.array_equal(np.asarray(ref.adj), np.asarray(pal.adj)), tile
    with pytest.raises(ValueError, match="commit_tile"):
        commit_batch(base, bids, nbr, sc, norms, commit_tile=0)


def test_commit_batch_pallas_backend_bit_exact(rng):
    """The commit_backend dispatch seam: a full commit (forward edges +
    reverse merge + size/entry advance) is bit-identical across backends."""
    from repro.core.build import commit_batch
    from repro.core.graph import empty_graph

    items = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    norms = jnp.linalg.norm(items, axis=-1)
    base = empty_graph(items, 4)
    bids = jnp.arange(32, dtype=jnp.int32)
    nbr = jnp.asarray(rng.integers(-1, 32, (32, 4)).astype(np.int32))
    sc = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    ref = commit_batch(base, bids, nbr, sc, norms)
    pal = commit_batch(base, bids, nbr, sc, norms, commit_backend="pallas")
    assert np.array_equal(np.asarray(ref.adj), np.asarray(pal.adj))
    assert int(ref.size) == int(pal.size)
    assert int(ref.entry) == int(pal.entry)
    assert float(ref.entry_norm) == float(pal.entry_norm)


def test_commit_batch_rejects_unknown_backend(rng):
    from repro.core.build import commit_batch
    from repro.core.graph import empty_graph

    items = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    g = empty_graph(items, 2)
    with pytest.raises(ValueError, match="commit_backend"):
        commit_batch(
            g, jnp.arange(2, dtype=jnp.int32),
            jnp.full((2, 2), -1, jnp.int32), jnp.zeros((2, 2), jnp.float32),
            jnp.linalg.norm(items, axis=-1), commit_backend="cuda",
        )


# ---------------------------------------------------------------------------
# beam_step: bit-exact single-step parity across the state-shape matrix
# ---------------------------------------------------------------------------


def _random_step_state(rng, b, l, m, v, n, d):
    """A plausible mid-walk state: sorted pool with -1 padding, partially
    checked slots, -1 padded adjacency and visited buffer, some done rows."""
    items = rng.normal(size=(n, d)).astype(np.float32)
    queries = rng.normal(size=(b, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    pool_ids = rng.integers(-1, n, size=(b, l)).astype(np.int32)
    pool_scores = np.where(
        pool_ids >= 0, rng.normal(size=(b, l)), -np.inf
    ).astype(np.float32)
    order = np.argsort(-pool_scores, axis=1, kind="stable")
    pool_ids = np.take_along_axis(pool_ids, order, 1)
    pool_scores = np.take_along_axis(pool_scores, order, 1)
    pool_checked = (rng.random(size=(b, l)) < 0.4) | (pool_ids < 0)
    visited = rng.integers(-1, n, size=(b, v)).astype(np.int32)
    done = rng.random(size=b) < 0.2
    return tuple(
        map(
            jnp.asarray,
            (pool_ids, pool_scores, pool_checked, visited, done, queries,
             adj, items),
        )
    )


@pytest.mark.parametrize(
    "b,l,m,v,n,d",
    [
        (1, 1, 1, 1, 10, 1),        # degenerate everything
        (2, 8, 4, 12, 64, 16),      # small round shapes
        (5, 16, 8, 40, 200, 33),    # odd d
        (3, 7, 5, 23, 111, 129),    # odd everything, d > 128
        (9, 64, 16, 100, 500, 48),  # paper-scale pool/degree
    ],
)
def test_beam_step_matches_ref_bit_exact(rng, b, l, m, v, n, d):
    args = _random_step_state(rng, b, l, m, v, n, d)
    r = beam_step_ref(*args)
    p = beam_step(*args)
    assert np.array_equal(np.asarray(r.pool_ids), np.asarray(p.pool_ids))
    assert np.array_equal(np.asarray(r.pool_checked), np.asarray(p.pool_checked))
    assert np.array_equal(np.asarray(r.nbr_ids), np.asarray(p.nbr_ids))
    assert np.array_equal(np.asarray(r.done), np.asarray(p.done))
    assert np.array_equal(np.asarray(r.n_scored), np.asarray(p.n_scored))
    np.testing.assert_allclose(
        np.asarray(r.pool_scores), np.asarray(p.pool_scores), rtol=1e-5, atol=1e-5
    )


def test_beam_step_all_done_is_noop(rng):
    args = _random_step_state(rng, 4, 8, 4, 16, 50, 8)
    done = jnp.ones((4,), bool)
    args = args[:4] + (done,) + args[5:]
    r = beam_step_ref(*args)
    p = beam_step(*args)
    assert np.all(np.asarray(r.done)) and np.all(np.asarray(p.done))
    assert np.array_equal(np.asarray(r.nbr_ids), np.full((4, 4), -1))
    assert np.array_equal(np.asarray(p.nbr_ids), np.full((4, 4), -1))
    assert np.all(np.asarray(p.n_scored) == 0)


def test_beam_step_n_dead_contract_both_backends(rng):
    """Without ``live=`` BOTH step implementations report ``n_dead=None``
    (not zeros — None means "not measured", and the serve/search layers key
    off that); with a mask both report identical int32 counts."""
    args = _random_step_state(rng, 4, 8, 4, 16, 50, 8)
    assert beam_step_ref(*args).n_dead is None
    assert beam_step(*args).n_dead is None

    live = jnp.asarray(rng.random(50) < 0.7)
    r = beam_step_ref(*args, live=live)
    p = beam_step(*args, live=live)
    assert r.n_dead is not None and p.n_dead is not None
    assert np.asarray(r.n_dead).dtype == np.int32
    assert np.array_equal(np.asarray(r.n_dead), np.asarray(p.n_dead))
    assert np.array_equal(np.asarray(r.n_scored), np.asarray(p.n_scored))
    assert (np.asarray(r.n_dead) <= np.asarray(r.n_scored)).all()


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_beam_search_dead_evals_none_without_live(rng, backend):
    """The full walk mirrors the step contract: ``SearchResult.dead_evals``
    is None unless a tombstone mask was supplied."""
    from repro.core.build import build_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(100, 8)).astype(np.float32))
    g = build_graph(items, max_degree=4, ef_construction=8, insert_batch=32)
    q = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (3, 1)).astype(jnp.int32)
    r = beam_search(g, q, init, pool_size=8, max_steps=8, k=3,
                    backend=backend)
    assert r.dead_evals is None
    r_live = beam_search(g, q, init, pool_size=8, max_steps=8, k=3,
                         backend=backend, live=jnp.ones(100, bool))
    assert r_live.dead_evals is not None
    assert (np.asarray(r_live.dead_evals) == 0).all()


# ---------------------------------------------------------------------------
# beam_step: full-walk parity — pallas backend vs reference beam_search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,d,b,md,pool,steps",
    [(300, 16, 5, 8, 16, 32), (200, 33, 3, 4, 8, 16), (400, 20, 7, 8, 24, 40)],
)
def test_beam_search_backend_parity(rng, n, d, b, md, pool, steps):
    from repro.core.build import build_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g = build_graph(items, max_degree=md, ef_construction=16, insert_batch=64)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    r1 = beam_search(g, q, init, pool_size=pool, max_steps=steps, k=5,
                     backend="reference")
    r2 = beam_search(g, q, init, pool_size=pool, max_steps=steps, k=5,
                     backend="pallas")
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    assert np.array_equal(np.asarray(r1.evals), np.asarray(r2.evals))
    assert np.array_equal(np.asarray(r1.visited), np.asarray(r2.visited))
    assert int(r1.steps) == int(r2.steps)


def test_beam_search_rejects_unknown_backend(rng):
    from repro.core.graph import empty_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    g = empty_graph(items, 2)
    q = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    init = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="backend"):
        beam_search(g, q, init, pool_size=2, max_steps=2, k=1, backend="cuda")


def test_pallas_backend_rejects_custom_score_fn(rng):
    from repro.core.graph import empty_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    g = empty_graph(items, 2)
    q = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    init = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="score_fn"):
        beam_search(g, q, init, pool_size=2, max_steps=2, k=1,
                    backend="pallas", score_fn=lambda q, x, i: q[:, :1] * 0)


def test_ipnsw_pallas_backend_end_to_end(rng):
    """The backend= knob threads through the index classes."""
    from repro.core import IpNSW

    items = jnp.asarray(rng.normal(size=(256, 24)).astype(np.float32))
    ref = IpNSW(max_degree=8, ef_construction=16, insert_batch=64).build(items)
    q = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    r1 = ref.search(q, k=5, ef=16)
    r2 = ref.search(q, k=5, ef=16, backend="pallas")
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
