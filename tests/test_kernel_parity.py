"""Kernel parity matrix: every kernels/*/ops.py vs its ref.py oracle, in
interpret mode, across shapes, odd (non-128-multiple) dims, and -1 padded
ids — including the fused beam_step kernel (bit-exact ids vs the reference
step and vs the reference full walk)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.beam_step import beam_step, beam_step_ref
from repro.kernels.gather_score import gather_score, gather_score_ref
from repro.kernels.mips_topk import mips_topk, mips_topk_ref
from repro.kernels.topk_merge import topk_merge, topk_merge_ref


# ---------------------------------------------------------------------------
# gather_score / mips_topk / topk_merge: odd dims + -1 padded ids
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,n,d,w",
    [(1, 40, 1, 1), (3, 100, 17, 5), (8, 333, 129, 9), (16, 512, 127, 16)],
)
def test_gather_score_odd_dims_and_padded_ids(rng, b, n, d, w):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = rng.integers(0, n, size=(b, w)).astype(np.int32)
    ids[rng.random(size=ids.shape) < 0.3] = -1  # -1 padding slots
    s = gather_score(q, x, jnp.asarray(ids))
    # oracle contract: ids pre-clamped (kernel scores -1 against row 0)
    r = gather_score_ref(q, x, jnp.asarray(np.maximum(ids, 0)))
    np.testing.assert_allclose(np.asarray(s), np.asarray(r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,n,d,k", [(2, 130, 31, 3), (5, 999, 65, 7)])
def test_mips_topk_odd_dims(rng, b, n, d, k):
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    vs, ids = mips_topk(q, x, k=k)
    rvs, rids = mips_topk_ref(q, x, k=k)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(rvs), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


@pytest.mark.parametrize("b,l,m", [(1, 1, 1), (3, 7, 5), (17, 33, 9)])
def test_topk_merge_odd_shapes_and_padded_ids(rng, b, l, m):
    pool_s = rng.normal(size=(b, l)).astype(np.float32)
    pool_i = rng.integers(-1, 100, (b, l)).astype(np.int32)
    pool_s[pool_i < 0] = -np.inf  # -1 slots carry -inf, like a real pool
    new_s = rng.normal(size=(b, m)).astype(np.float32)
    new_i = rng.integers(-1, 100, (b, m)).astype(np.int32)
    new_s[new_i < 0] = -np.inf
    args = (
        pool_s, pool_i, rng.integers(0, 2, (b, l)).astype(np.int32),
        new_s, new_i, rng.integers(0, 2, (b, m)).astype(np.int32),
    )
    out = topk_merge(*map(jnp.asarray, args))
    ref = topk_merge_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    assert np.array_equal(np.asarray(out[2]), np.asarray(ref[2]))


# ---------------------------------------------------------------------------
# flash_attn: representative cell so the matrix covers every kernel pair
# (tile-granular kernel — block shape sweeps live in test_kernels.py)
# ---------------------------------------------------------------------------


def test_flash_attn_parity_cell(rng):
    from repro.kernels.flash_attn import flash_attention_head, flash_attention_head_ref

    q = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    out = flash_attention_head(q, k, v, bq=64, bk=64)
    ref = flash_attention_head_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# beam_step: bit-exact single-step parity across the state-shape matrix
# ---------------------------------------------------------------------------


def _random_step_state(rng, b, l, m, v, n, d):
    """A plausible mid-walk state: sorted pool with -1 padding, partially
    checked slots, -1 padded adjacency and visited buffer, some done rows."""
    items = rng.normal(size=(n, d)).astype(np.float32)
    queries = rng.normal(size=(b, d)).astype(np.float32)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    pool_ids = rng.integers(-1, n, size=(b, l)).astype(np.int32)
    pool_scores = np.where(
        pool_ids >= 0, rng.normal(size=(b, l)), -np.inf
    ).astype(np.float32)
    order = np.argsort(-pool_scores, axis=1, kind="stable")
    pool_ids = np.take_along_axis(pool_ids, order, 1)
    pool_scores = np.take_along_axis(pool_scores, order, 1)
    pool_checked = (rng.random(size=(b, l)) < 0.4) | (pool_ids < 0)
    visited = rng.integers(-1, n, size=(b, v)).astype(np.int32)
    done = rng.random(size=b) < 0.2
    return tuple(
        map(
            jnp.asarray,
            (pool_ids, pool_scores, pool_checked, visited, done, queries,
             adj, items),
        )
    )


@pytest.mark.parametrize(
    "b,l,m,v,n,d",
    [
        (1, 1, 1, 1, 10, 1),        # degenerate everything
        (2, 8, 4, 12, 64, 16),      # small round shapes
        (5, 16, 8, 40, 200, 33),    # odd d
        (3, 7, 5, 23, 111, 129),    # odd everything, d > 128
        (9, 64, 16, 100, 500, 48),  # paper-scale pool/degree
    ],
)
def test_beam_step_matches_ref_bit_exact(rng, b, l, m, v, n, d):
    args = _random_step_state(rng, b, l, m, v, n, d)
    r = beam_step_ref(*args)
    p = beam_step(*args)
    assert np.array_equal(np.asarray(r.pool_ids), np.asarray(p.pool_ids))
    assert np.array_equal(np.asarray(r.pool_checked), np.asarray(p.pool_checked))
    assert np.array_equal(np.asarray(r.nbr_ids), np.asarray(p.nbr_ids))
    assert np.array_equal(np.asarray(r.done), np.asarray(p.done))
    assert np.array_equal(np.asarray(r.n_scored), np.asarray(p.n_scored))
    np.testing.assert_allclose(
        np.asarray(r.pool_scores), np.asarray(p.pool_scores), rtol=1e-5, atol=1e-5
    )


def test_beam_step_all_done_is_noop(rng):
    args = _random_step_state(rng, 4, 8, 4, 16, 50, 8)
    done = jnp.ones((4,), bool)
    args = args[:4] + (done,) + args[5:]
    r = beam_step_ref(*args)
    p = beam_step(*args)
    assert np.all(np.asarray(r.done)) and np.all(np.asarray(p.done))
    assert np.array_equal(np.asarray(r.nbr_ids), np.full((4, 4), -1))
    assert np.array_equal(np.asarray(p.nbr_ids), np.full((4, 4), -1))
    assert np.all(np.asarray(p.n_scored) == 0)


# ---------------------------------------------------------------------------
# beam_step: full-walk parity — pallas backend vs reference beam_search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,d,b,md,pool,steps",
    [(300, 16, 5, 8, 16, 32), (200, 33, 3, 4, 8, 16), (400, 20, 7, 8, 24, 40)],
)
def test_beam_search_backend_parity(rng, n, d, b, md, pool, steps):
    from repro.core.build import build_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g = build_graph(items, max_degree=md, ef_construction=16, insert_batch=64)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    r1 = beam_search(g, q, init, pool_size=pool, max_steps=steps, k=5,
                     backend="reference")
    r2 = beam_search(g, q, init, pool_size=pool, max_steps=steps, k=5,
                     backend="pallas")
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    assert np.array_equal(np.asarray(r1.evals), np.asarray(r2.evals))
    assert np.array_equal(np.asarray(r1.visited), np.asarray(r2.visited))
    assert int(r1.steps) == int(r2.steps)


def test_beam_search_rejects_unknown_backend(rng):
    from repro.core.graph import empty_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    g = empty_graph(items, 2)
    q = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    init = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="backend"):
        beam_search(g, q, init, pool_size=2, max_steps=2, k=1, backend="cuda")


def test_pallas_backend_rejects_custom_score_fn(rng):
    from repro.core.graph import empty_graph
    from repro.core.search import beam_search

    items = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    g = empty_graph(items, 2)
    q = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    init = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="score_fn"):
        beam_search(g, q, init, pool_size=2, max_steps=2, k=1,
                    backend="pallas", score_fn=lambda q, x, i: q[:, :1] * 0)


def test_ipnsw_pallas_backend_end_to_end(rng):
    """The backend= knob threads through the index classes."""
    from repro.core import IpNSW

    items = jnp.asarray(rng.normal(size=(256, 24)).astype(np.float32))
    ref = IpNSW(max_degree=8, ef_construction=16, insert_batch=64).build(items)
    q = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    r1 = ref.search(q, k=5, ef=16)
    r2 = ref.search(q, k=5, ef=16, backend="pallas")
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
