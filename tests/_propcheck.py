"""Minimal offline stand-in for the hypothesis subset these tests use.

The container may not ship hypothesis; CI installs the real package.  This
fallback implements only what the property tests need — ``given``/``settings``
and the ``integers``/``floats``/``lists``/``flatmap``/``map`` strategies —
drawing deterministically (example ``i`` always uses ``default_rng(i)``), so
failures reproduce without shrinking.
"""
from __future__ import annotations

import numpy as np


class Strategy:
    def __init__(self, draw):
        self.draw = draw  # draw(rng) -> value

    def flatmap(self, f):
        return Strategy(lambda rng: f(self.draw(rng)).draw(rng))

    def map(self, f):
        return Strategy(lambda rng: f(self.draw(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return Strategy(
            lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ]
        )


st = _Strategies()


def settings(max_examples=25, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        n = getattr(fn, "_max_examples", 25)

        def runner():
            for i in range(n):
                rng = np.random.default_rng(i)
                vals = [s.draw(rng) for s in strats]
                try:
                    fn(*vals)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i}: {vals!r}"
                    ) from e

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
