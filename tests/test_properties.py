"""Property-based tests (hypothesis) for system invariants.

REPRO_TEST_QUICK=1 shrinks example counts and Monte-Carlo sizes (consistent
with REPRO_BENCH_QUICK for benchmarks); the heaviest cases carry
``@pytest.mark.slow`` so ``-m "not slow"`` gives a fast local loop.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; CI installs the real one
    from _propcheck import given, settings, st

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

from repro.core.distributed import norm_band_partition
from repro.core.search import _dedup_ids
from repro.core.norms import (
    norm_group_of,
    group_occupancy,
    theorem1_probability,
    theorem2_conditional,
)
from repro.obs.recall import recall_at_k
from repro.kernels.topk_merge import topk_merge, topk_merge_ref
from repro.models.recsys.embedding import embedding_bag, embedding_bag_ragged

SETTINGS = dict(max_examples=5 if QUICK else 25, deadline=None)


@given(
    st.integers(1, 6).flatmap(
        lambda b: st.lists(
            st.lists(st.integers(-1, 20), min_size=4, max_size=4),
            min_size=b,
            max_size=b,
        )
    )
)
@settings(**SETTINGS)
def test_dedup_ids_removes_duplicates(rows):
    ids = jnp.asarray(np.array(rows, dtype=np.int32))
    out = np.asarray(_dedup_ids(ids))
    for r_in, r_out in zip(np.asarray(ids), out):
        kept = r_out[r_out >= 0]
        # no duplicates survive
        assert len(set(kept.tolist())) == len(kept)
        # every unique non-negative id is kept exactly once
        expect = set(x for x in r_in.tolist() if x >= 0)
        assert set(kept.tolist()) == expect


@given(st.floats(1.0, 16.0))
@settings(**SETTINGS)
def test_theorem1_bounds_and_monotonicity(alpha):
    p = theorem1_probability(alpha)
    assert 0.5 - 1e-6 <= p <= 1.0
    assert theorem1_probability(alpha + 1.0) >= p - 1e-9


def test_theorem1_alpha1_is_half():
    assert abs(theorem1_probability(1.0) - 0.5) < 1e-4


@pytest.mark.slow
@given(
    st.floats(0.1, 0.999),
    st.floats(0.1, 10.0),
    st.floats(0.1, 10.0),
    st.floats(0.1, 10.0),
)
@settings(**SETTINGS)
def test_theorem2_conditional_matches_monte_carlo(beta, gamma, xn, yn):
    """x.z | y.z = gamma is N(gamma*beta*|x|/|y|, |x|^2(1-beta^2)) — checked
    against explicit construction of x with angle beta to y."""
    d = 1024 if QUICK else 4096
    n_mc = 5000 if QUICK else 20000
    rng = np.random.default_rng(0)
    y = np.zeros(d)
    y[0] = yn
    x = np.zeros(d)
    x[0] = beta * xn
    x[1] = np.sqrt(max(1 - beta**2, 0.0)) * xn
    mean, std = theorem2_conditional(beta, gamma, xn, yn)
    # z conditioned on y.z = gamma: z0 = gamma/yn, others free N(0,1)
    z = rng.normal(size=(n_mc, d))
    z[:, 0] = gamma / yn
    xz = z @ x
    assert abs(xz.mean() - mean) < 5 * std / np.sqrt(n_mc) + 1e-3
    assert abs(xz.std() - std) < 0.05 * std + 1e-3


@given(st.integers(1, 200), st.integers(1, 24), st.integers(0, 3))
@settings(**SETTINGS)
def test_norm_band_partition_is_permutation_with_true_bounds(n, p, dist):
    """The two invariants the shard-routing skip rule rests on
    (core/distributed.py): the union of the bands is an EXACT permutation of
    the catalog (no item lost or duplicated by banding), and every band's
    recorded max_norm is a TRUE upper bound on its members — if either
    broke, a "provably unable" skipped shard could actually hold a top-k
    answer.  Also pins the ordering contract: band 0 holds the largest
    norms, bands are count-balanced to ceil(n/p), and ties break
    deterministically (stable by id)."""
    rng = np.random.default_rng(n * 97 + p * 13 + dist)
    norms = [
        rng.uniform(0.0, 2.0, n),
        rng.lognormal(0.0, 0.6, n),
        np.full(n, 1.0),                       # all ties
        np.round(rng.uniform(0, 3, n)),        # heavy ties
    ][dist]
    bands, band_max = norm_band_partition(norms, p)
    assert len(bands) == p and band_max.shape == (p,)
    # exact permutation
    union = np.concatenate([b for b in bands]) if p else np.array([])
    assert sorted(union.tolist()) == list(range(n))
    # count balance: every band holds ceil(n/p) items except a ragged tail
    per = -(-n // p)
    assert all(len(b) == per for b in bands[: n // per])
    # true upper bound, and descending band order
    prev_min = np.inf
    for b, mx in zip(bands, band_max):
        if len(b) == 0:
            assert mx == 0.0
            continue
        assert norms[b].max() <= mx + 1e-12
        assert norms[b].max() <= prev_min + 1e-12   # bands are norm-sorted
        prev_min = norms[b].min()
    # determinism (stable tie-break): same input, same partition
    bands2, _ = norm_band_partition(norms, p)
    for a, b in zip(bands, bands2):
        assert np.array_equal(a, b)


@given(st.integers(5, 200), st.integers(1, 20))
@settings(**SETTINGS)
def test_norm_groups_partition(n, n_groups):
    rng = np.random.default_rng(n)
    norms = rng.uniform(0.1, 2.0, n)
    g = norm_group_of(norms, n_groups)
    assert g.min() >= 0 and g.max() < n_groups
    occ = group_occupancy(np.arange(n), g, n_groups)
    assert abs(occ.sum() - 1.0) < 1e-9
    # the top group holds the largest norms
    top = norms[g == 0]
    rest = norms[g != 0]
    if len(top) and len(rest):
        assert top.min() >= rest.max() - 1e-12


@pytest.mark.slow
@given(st.integers(1, 40), st.integers(1, 16), st.integers(1, 16))
@settings(**SETTINGS)
def test_topk_merge_property(b, l, m):
    rng = np.random.default_rng(b * 1000 + l * 16 + m)
    args = (
        rng.normal(size=(b, l)).astype(np.float32),
        rng.integers(0, 100, (b, l)).astype(np.int32),
        rng.integers(0, 2, (b, l)).astype(np.int32),
        rng.normal(size=(b, m)).astype(np.float32),
        rng.integers(0, 100, (b, m)).astype(np.int32),
        rng.integers(0, 2, (b, m)).astype(np.int32),
    )
    out = topk_merge(*map(jnp.asarray, args))
    ref = topk_merge_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]), rtol=1e-6)
    # merged scores are sorted descending
    s = np.asarray(out[0])
    assert np.all(np.diff(s, axis=1) <= 1e-6)


@pytest.mark.slow
@given(st.integers(1, 8), st.integers(1, 10), st.integers(2, 50))
@settings(**SETTINGS)
def test_embedding_bag_padded_equals_ragged(b, lmax, v):
    rng = np.random.default_rng(b * 100 + lmax * 7 + v)
    table = jnp.asarray(rng.normal(size=(v, 8)).astype(np.float32))
    lengths = rng.integers(1, lmax + 1, b)
    ids = np.full((b, lmax), -1, np.int32)
    flat, offs = [], [0]
    for i, L in enumerate(lengths):
        row = rng.integers(0, v, L)
        ids[i, :L] = row
        flat.extend(row.tolist())
        offs.append(offs[-1] + L)
    a = embedding_bag(table, jnp.asarray(ids), mode="sum")
    r = embedding_bag_ragged(
        table, jnp.asarray(np.array(flat, np.int32)), jnp.asarray(np.array(offs, np.int32))
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-5, atol=1e-5)


@given(st.integers(2, 30), st.integers(1, 10))
@settings(**SETTINGS)
def test_recall_at_k_properties(b, k):
    rng = np.random.default_rng(b * 31 + k)
    true = rng.integers(0, 1000, (b, k)).astype(np.int32)
    assert recall_at_k(true, true) == 1.0
    miss = true + 10_000
    assert recall_at_k(miss, true) == 0.0
