"""Core index library: build invariants, search quality, metrics, baselines."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    IpNSW,
    IpNSWPlus,
    SimpleLSH,
    exact_topk,
    in_degrees,
    out_degrees,
    recall_at_k,
)
from repro.core.build import build_graph
from repro.core.similarity import Similarity, normalize
from repro.data import mips_dataset, mips_queries


@pytest.fixture(scope="module")
def dataset():
    items = jnp.asarray(mips_dataset(3000, 32, "lognormal", seed=0))
    queries = jnp.asarray(mips_queries(64, 32, seed=1))
    _, gt = exact_topk(queries, items, k=10)
    return items, queries, np.asarray(gt)


def test_build_invariants(dataset):
    items, _, _ = dataset
    g = build_graph(items, max_degree=12, ef_construction=24, insert_batch=256)
    adj = np.asarray(g.adj)
    n, m = adj.shape
    assert m == 12
    # ids in range, no self loops
    valid = adj[adj >= 0]
    assert valid.max() < n
    rows = np.broadcast_to(np.arange(n)[:, None], adj.shape)
    assert not np.any(adj == rows), "self loop"
    # out-degree bounded by construction
    assert out_degrees(g).max() <= 12
    # no duplicate neighbors within a row
    for r in adj[:100]:
        v = r[r >= 0]
        assert len(set(v.tolist())) == len(v)


def test_ipnsw_recall(dataset):
    items, queries, gt = dataset
    idx = IpNSW(max_degree=16, ef_construction=32, insert_batch=256).build(items)
    res = idx.search(queries, k=10, ef=80)
    rec = recall_at_k(np.asarray(res.ids), gt)
    assert rec > 0.85, rec
    # evals strictly fewer than brute force
    assert float(np.mean(np.asarray(res.evals))) < items.shape[0] * 0.8


def test_ipnsw_plus_recall_and_paper_claim(dataset):
    """ip-NSW+ >= ip-NSW recall at matched pool size (paper Fig 7/8a trend)."""
    items, queries, gt = dataset
    base = IpNSW(max_degree=16, ef_construction=32, insert_batch=256).build(items)
    plus = IpNSWPlus(max_degree=16, ef_construction=32, insert_batch=256).build(items)
    r_base = base.search(queries, k=10, ef=40)
    r_plus = plus.search(queries, k=10, ef=40)
    rec_b = recall_at_k(np.asarray(r_base.ids), gt)
    rec_p = recall_at_k(np.asarray(r_plus.ids), gt)
    assert rec_p >= rec_b - 0.02, (rec_p, rec_b)
    # eval accounting: plus counts angular + ip evaluations
    ev = np.asarray(r_plus.evals)
    assert np.all(ev == np.asarray(r_plus.ang_evals) + np.asarray(r_plus.ip_evals))


def test_exact_topk_is_exact(dataset):
    items, queries, _ = dataset
    v1, i1 = exact_topk(queries, items, k=10, backend="jnp")
    scores = np.asarray(queries) @ np.asarray(items).T
    gt = np.argsort(-scores, axis=1)[:, :10]
    assert np.array_equal(np.asarray(i1), gt)


def test_exact_topk_pallas_backend(dataset):
    items, queries, _ = dataset
    v1, i1 = exact_topk(queries, items, k=10, backend="jnp")
    v2, i2 = exact_topk(queries, items, k=10, backend="pallas")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_simple_lsh_recall_improves_with_candidates(dataset):
    items, queries, gt = dataset
    lsh = SimpleLSH(n_bits=96).build(items)
    r_small = lsh.search(queries, k=10, n_candidates=50)
    r_big = lsh.search(queries, k=10, n_candidates=800)
    rec_s = recall_at_k(np.asarray(r_small.ids), gt)
    rec_b = recall_at_k(np.asarray(r_big.ids), gt)
    assert rec_b > rec_s
    assert rec_b > 0.5


def test_angular_graph_uses_normalized_items(dataset):
    items, _, _ = dataset
    g = build_graph(items, similarity=Similarity.ANGULAR, max_degree=8,
                    ef_construction=16, insert_batch=256)
    norms = np.linalg.norm(np.asarray(g.items), axis=1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)


def test_in_degree_unbounded_out_degree_bounded(dataset):
    items, _, _ = dataset
    g = build_graph(items, max_degree=8, ef_construction=16, insert_batch=256)
    ind = in_degrees(g)
    assert ind.max() > 8, "in-degree should exceed M (paper Fig 4 premise)"


def test_reverse_links_flag(dataset):
    """reverse_links=False reproduces the printed Algorithm 2 (directed)."""
    items, _, _ = dataset
    g = build_graph(items, max_degree=8, ef_construction=16,
                    insert_batch=256, reverse_links=False)
    adj = np.asarray(g.adj)
    # directed build: early rows only point to earlier items
    for i in range(1, 50):
        nbrs = adj[i][adj[i] >= 0]
        assert np.all(nbrs < i)


def test_hierarchical_ipnsw(dataset):
    from repro.core import HierarchicalIpNSW

    items, queries, gt = dataset
    h = HierarchicalIpNSW(max_degree=12, ef_construction=24,
                          insert_batch=512).build(items)
    # geometric level sizes, all items at level 0
    sizes = [g.items.shape[0] for g in h.levels]
    assert sizes[0] == items.shape[0]
    assert all(sizes[i] > sizes[i + 1] for i in range(len(sizes) - 1))
    r = h.search(queries, k=10, ef=64)
    assert recall_at_k(np.asarray(r.ids), gt) > 0.8


def test_norm_filtered_index(dataset):
    from repro.core import NormFilteredIndex
    from repro.core.norms import top_group_share

    items, queries, gt = dataset
    norms = np.linalg.norm(np.asarray(items), axis=1)
    nf = NormFilteredIndex(keep_frac=0.25, plus=True, max_degree=12,
                           ef_construction=24, insert_batch=512).build(items)
    assert len(nf.global_ids) == int(items.shape[0] * 0.25)
    r = nf.search(queries, k=10, ef=64)
    rec = recall_at_k(np.asarray(r.ids), gt)
    bound = top_group_share(gt, norms, 25.0)
    # achieves most of the slice's ground-truth occupancy bound
    assert rec > 0.6 * bound, (rec, bound)
    # returned ids must be members of the kept slice
    ids = np.asarray(r.ids)
    kept = set(nf.global_ids.tolist())
    assert all(i in kept for i in ids[ids >= 0].tolist())
