"""Observability-layer suite (repro.obs + the trace plumbing through
core/search and the serving loop).

The load-bearing pins, in dependency order:

  * trace-off parity  — passing ``trace=None`` (the default) is BIT-identical
    to the pre-observability walk on every axis (backend × storage × index
    kind), and passing a TraceContext leaves ids/scores/evals bit-identical
    too: the trace is computed post-loop from the visited buffer, never
    inside the walk.
  * trace semantics   — static shapes from (trace_cap, n_bands), the
    column->step map, band_hist rows summing exactly to the walk's eval
    counts, hub/steps reductions bounded by the walk geometry.
  * norm bias         — on a lognormal (word_like) catalog the top norm
    decile receives the MAJORITY of evaluations (the paper's Fig-5 claim,
    now a regression pin).
  * serve integration — a registry+trace run of the virtual-clock loop keeps
    ZERO steady-state recompiles, replays to a bit-identical registry, and
    its JSONL export renders through scripts/obs_report.py alone.
  * registry contract — get-or-create metrics, hard error on type drift,
    Prometheus text shape, JSONL round-trip.
"""
import functools
import io
import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import IpNSW, IpNSWPlus
from repro.core.search import beam_search
from repro.data import mips_dataset, mips_queries
from repro.obs import (
    MetricsRegistry,
    make_trace_context,
    step_of_column,
    top_band_share,
    write_metrics,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Large enough for the paper's norm-bias concentration to manifest (the
# top-decile pin sits near 0.87 here; at N=400 it is only ~0.42).
N, D, K = 2000, 24, 5


@functools.lru_cache(maxsize=None)
def _items():
    # lognormal: the word_like / Fig-5 regime where norm bias is strongest
    return jnp.asarray(mips_dataset(N, D, "lognormal", seed=3))


@functools.lru_cache(maxsize=None)
def _index():
    return IpNSW(max_degree=8, ef_construction=16, insert_batch=256).build(
        _items()
    )


@functools.lru_cache(maxsize=None)
def _plus_index():
    return IpNSWPlus(max_degree=8, ef_construction=16,
                     insert_batch=256).build(_items())


@functools.lru_cache(maxsize=None)
def _ctx(trace_cap: int = 64, n_bands: int = 10):
    index = _index()
    norms = np.linalg.norm(np.asarray(index.graph.items), axis=1)
    return make_trace_context(norms, np.asarray(index.graph.adj),
                              trace_cap=trace_cap, n_bands=n_bands)


def _queries(b=8, seed=7):
    return jnp.asarray(mips_queries(b, D, seed=seed))


# ---------------------------------------------------------------------------
# trace-off / trace-on parity — the walk is untouched on every axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_trace_leaves_walk_bit_identical_ipnsw(backend, storage):
    q = _queries()
    base = _index().search(q, k=K, ef=16, backend=backend, storage=storage)
    traced = _index().search(q, k=K, ef=16, backend=backend,
                             storage=storage, trace=_ctx())
    assert base.trace is None
    assert traced.trace is not None
    for field in ("ids", "scores", "evals", "visited"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, field)),
            np.asarray(getattr(traced, field)),
            err_msg=f"{backend}/{storage}: {field} changed under tracing",
        )


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_trace_leaves_walk_bit_identical_plus(backend):
    q = _queries()
    base = _plus_index().search(q, k=K, ef=16, backend=backend)
    traced = _plus_index().search(q, k=K, ef=16, backend=backend,
                                  trace=_ctx())
    assert base.trace is None and traced.trace is not None
    for field in ("ids", "scores", "ip_evals", "ang_evals"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, field)),
            np.asarray(getattr(traced, field)),
            err_msg=f"plus/{backend}: {field} changed under tracing",
        )


def test_trace_context_size_mismatch_raises():
    wrong = make_trace_context(np.ones(N + 3, np.float32))
    with pytest.raises(ValueError, match="trace context covers"):
        _index().search(_queries(2), k=K, ef=16, trace=wrong)


# ---------------------------------------------------------------------------
# trace semantics — shapes, step map, reduction invariants
# ---------------------------------------------------------------------------


def test_trace_shapes_and_step_map():
    b, cap, bands = 6, 32, 10
    r = _index().search(_queries(b), k=K, ef=16,
                        trace=_ctx(trace_cap=cap, n_bands=bands))
    tr = r.trace
    assert tr.ids.shape == (b, cap) and tr.scores.shape == (b, cap)
    assert tr.step.shape == (cap,)
    assert tr.band_hist.shape == (b, bands)
    assert tr.hub_evals.shape == (b,) and tr.steps_to_converge.shape == (b,)
    # the static column->step map: seed columns are step 0, later columns
    # belong to non-decreasing expansion rounds
    step = np.asarray(tr.step)
    assert step[0] == 0
    assert (np.diff(step) >= 0).all()
    # ids prefix IS the visited prefix; pads are -1 with -inf scores
    np.testing.assert_array_equal(
        np.asarray(tr.ids), np.asarray(r.visited[:, :cap])
    )
    pads = np.asarray(tr.ids) < 0
    assert np.isneginf(np.asarray(tr.scores)[pads]).all()
    assert np.isfinite(np.asarray(tr.scores)[~pads]).all()


def test_trace_cap_truncates_and_caps_at_buffer():
    r_small = _index().search(_queries(4), k=K, ef=16,
                              trace=_ctx(trace_cap=8))
    assert r_small.trace.ids.shape[1] == 8
    huge = 10_000
    r_full = _index().search(_queries(4), k=K, ef=16,
                             trace=_ctx(trace_cap=huge))
    v = r_full.visited.shape[1]
    assert r_full.trace.ids.shape[1] == v < huge
    np.testing.assert_array_equal(
        np.asarray(r_full.trace.ids), np.asarray(r_full.visited)
    )


def test_step_of_column_map():
    m = step_of_column(1 + 3 * 4, seeds=1, degree=4)
    np.testing.assert_array_equal(
        m, [0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3]
    )


@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_band_hist_sums_to_evals(storage):
    r = _index().search(_queries(), k=K, ef=16, storage=storage,
                        trace=_ctx())
    tr = r.trace
    np.testing.assert_array_equal(
        np.asarray(tr.band_hist).sum(axis=1), np.asarray(r.evals)
    )
    assert (np.asarray(tr.hub_evals) <= np.asarray(r.evals)).all()
    assert (np.asarray(tr.steps_to_converge) >= 1).all()
    assert (np.asarray(tr.steps_to_converge) <= int(r.steps)).all()


def test_padded_rows_trace_as_zero():
    q = _queries(4)
    valid = jnp.asarray([True, True, False, False])
    r = _index().search(q, k=K, ef=16, valid=valid, trace=_ctx())
    band = np.asarray(r.trace.band_hist)
    assert band[2:].sum() == 0 and band[:2].sum() > 0
    assert (np.asarray(r.trace.ids)[2:] == -1).all()


def test_lognormal_top_decile_gets_majority_of_evals():
    """The paper's Fig-5 norm-bias claim as a live pin: on a heavy-tailed
    catalog the top norm decile receives > 50% of all similarity evals."""
    r = _index().search(_queries(16, seed=11), k=K, ef=16, trace=_ctx())
    share = top_band_share(np.asarray(r.trace.band_hist).sum(axis=0))
    assert share > 0.5, f"top-decile share {share:.3f} <= 0.5"


def test_make_trace_context_validation_and_clipping():
    with pytest.raises(ValueError, match="size"):
        make_trace_context(np.ones(10, np.float32), size=11)
    with pytest.raises(ValueError, match="trace_cap"):
        make_trace_context(np.ones(10, np.float32), trace_cap=0)
    # out-of-range norms (capacity slots, churned-in items) clip into the
    # end bands instead of indexing out of bounds
    norms = np.concatenate([np.linspace(1, 2, 100), [0.0, 99.0]])
    ctx = make_trace_context(norms.astype(np.float32), size=100)
    ids = np.asarray(ctx.band_ids)
    assert ids[100] == 0 and ids[101] == 9


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------


def test_registry_get_or_create_and_type_drift():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    assert reg.counter("x_total") is c
    c.inc(2)
    assert reg.get("x_total").value == 2
    with pytest.raises(TypeError, match="x_total"):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        c.inc(-1)
    vec = reg.vector("by_band", 4, label="band")
    vec.add([1, 2, 3, 4])
    with pytest.raises(ValueError):
        vec.add([1, 2])
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    assert h.count == 2 and h.counts == [1, 0, 1]


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3)
    reg.gauge("debt").set(0.5)
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    reg.vector("by_band", 2, label="band").add([1, 2])
    text = reg.to_prometheus()
    assert "# TYPE req_total counter\nreq_total 3" in text
    assert "debt 0.5" in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text
    assert 'by_band{band="1"} 2' in text


def test_registry_jsonl_roundtrip(tmp_path):
    from repro.obs import load_jsonl

    reg = MetricsRegistry()
    reg.counter("a_total").inc(7)
    reg.histogram("h_seconds", buckets=(1.0,)).observe(0.5)
    reg.event("response", 1.25, rid=0, latency_s=0.01)
    path = str(tmp_path / "run.jsonl")
    assert write_metrics(reg, path, meta={"mode": "test"}) == "jsonl"
    snap = load_jsonl(path)
    assert snap["meta"]["mode"] == "test"
    assert snap["metrics"]["a_total"]["value"] == 7
    assert snap["metrics"]["h_seconds"]["count"] == 1
    assert snap["events"] == [
        {"event": "response", "t": 1.25, "rid": 0, "latency_s": 0.01}
    ]
    prom = str(tmp_path / "run.prom")
    assert write_metrics(reg, prom) == "prometheus"
    assert "a_total 7" in open(prom).read()


def test_registry_span_and_global_swap():
    from repro.obs import get_registry, set_registry

    reg = MetricsRegistry()
    prev = set_registry(reg)
    try:
        with get_registry().span("phase"):
            pass
        assert reg.get("phase_seconds").count == 1
    finally:
        set_registry(prev)


def test_build_emits_phase_spans():
    from repro.obs import get_registry, set_registry

    reg = MetricsRegistry()
    prev = set_registry(reg)
    try:
        IpNSW(max_degree=8, ef_construction=16, insert_batch=256).build(
            _items()
        )
    finally:
        set_registry(prev)
    assert reg.get("build_bootstrap_seconds").count >= 1
    assert reg.get("build_insert_seconds").count >= 1


# ---------------------------------------------------------------------------
# serving-loop integration — zero steady recompiles, deterministic registry,
# and the obs_report.py CLI end-to-end from the JSONL alone
# ---------------------------------------------------------------------------


def _serve_once(registry, trace_ctx, n_requests=48):
    from repro.launch.serve_loop import (
        BucketLadder,
        LinearServiceModel,
        ServeLoop,
        VirtualClock,
        poisson_trace,
    )

    queries = mips_queries(n_requests, D, seed=5)
    trace = poisson_trace(queries, rate_qps=400.0, seed=0, ef=16,
                          classes=("interactive", "standard", "relaxed"))
    loop = ServeLoop(
        _index(), ladder=BucketLadder(batches=(2, 4), efs=(8, 16)),
        clock=VirtualClock(), k=K, service_model=LinearServiceModel(),
        registry=registry, trace_ctx=trace_ctx,
    )
    return loop.run(trace)


def test_serve_loop_traced_keeps_zero_steady_recompiles():
    reg = MetricsRegistry()
    stats = _serve_once(reg, _ctx())
    s = stats.summary()
    assert s["served"] == 48
    assert s["recompiles_steady"] == 0
    assert reg.get("serve_recompiles_steady").value == 0
    assert reg.get("serve_requests_total").value == 48
    assert reg.get("serve_batches_total").value == s["batches"]
    # the always-on walk reductions flowed through the executor
    band = reg.get("walk_evals_by_band").values
    assert band.sum() == reg.get("walk_evals_total").value > 0
    assert reg.get("walk_hub_evals_total").value > 0
    assert reg.get("serve_latency_seconds").count == 48
    # lognormal catalog => the Fig-5 signal is visible from served traffic
    assert top_band_share(band) > 0.5


def test_serve_loop_registry_is_deterministic():
    """Virtual clock + injected registry => bit-identical exports across
    runs (the registry never reads wall time on the serve path)."""
    regs = []
    for _ in range(2):
        reg = MetricsRegistry()
        _serve_once(reg, _ctx())
        regs.append(reg)
    assert regs[0].collect() == regs[1].collect()
    assert regs[0].events == regs[1].events


def test_obs_report_renders_exported_jsonl(tmp_path):
    """The acceptance path: a traced serve run's JSONL alone reproduces the
    norm-bias concentration through scripts/obs_report.py."""
    reg = MetricsRegistry()
    _serve_once(reg, _ctx())
    path = str(tmp_path / "serve.jsonl")
    write_metrics(reg, path, meta={"mode": "loop", "profile": "lognormal"})

    script = os.path.join(ROOT, "scripts", "obs_report.py")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run([sys.executable, script, path],
                         capture_output=True, text=True, env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    out = res.stdout
    assert "evals by catalog norm band" in out
    assert "latency timeline" in out
    share = float(
        [ln for ln in out.splitlines()
         if ln.startswith("top_decile_share=")][0].split("=")[1]
    )
    assert share > 0.5


def test_report_function_summary(tmp_path):
    sys.path.insert(0, os.path.join(ROOT, "scripts"))
    try:
        import obs_report
    finally:
        sys.path.pop(0)
    reg = MetricsRegistry()
    _serve_once(reg, _ctx())
    path = str(tmp_path / "serve.jsonl")
    write_metrics(reg, path)
    buf = io.StringIO()
    summary = obs_report.report(path, out=buf)
    assert summary["top_decile_share"] > 0.5
    assert summary["serve_requests_total"] == 48
    assert 0.0 < summary["hub_eval_share"] < 1.0


# ---------------------------------------------------------------------------
# deprecation shim — core.metrics forwards to obs.recall
# ---------------------------------------------------------------------------


def test_core_metrics_shim_warns_and_matches():
    import importlib
    import warnings

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.core.metrics as legacy
        importlib.reload(legacy)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)

    from repro.obs.recall import recall_at_k
    assert legacy.recall_at_k is recall_at_k
