"""Fake-clock determinism suite for the continuous-batching serving loop
(launch/serve_loop.py).

Everything here runs in VIRTUAL time: the loop's only time source is the
injected VirtualClock and every dispatch advances it by the deterministic
LinearServiceModel — so the pins are exact, not statistical:

  * replay          — same arrival trace => bit-identical batch composition
                      (dispatch times, buckets, member rids) and bit-identical
                      response ids/scores across runs;
  * padding         — a query served inside a padded bucket returns exactly
                      the ids/scores of a direct ``search`` at the same ef
                      (beam_search's ``valid=`` contract);
  * admission       — largest fitting ef, degrade-to-smaller-ef before
                      reject (requests are NEVER rejected), FIFO within a
                      deadline class, earlier deadlines preempt later ones;
  * recompiles      — one compile per ladder bucket at warmup, zero steady
                      state, across repeated runs (serve.py's regression
                      meter);
  * wall-clock free — the virtual path never touches the ``time`` module
                      (pinned by poisoning serve_loop's reference to it).

The single wall-clock smoke test carries ``slow`` and is skipped in the
quick (REPRO_TEST_QUICK=1) tier so CI stays purely virtual-time.
"""
import functools
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import IpNSW, IpNSWPlus
from repro.data import mips_dataset, mips_queries
from repro.launch.serve_loop import (
    Bucket,
    BucketExecutor,
    BucketLadder,
    LinearServiceModel,
    Request,
    ServeLoop,
    VirtualClock,
    WallClock,
    poisson_trace,
)

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

N, D, K = 400, 16, 5
LADDER = BucketLadder(batches=(2, 4), efs=(8, 16, 32))
# service = 1ms + 1ms * ef: ef 8/16/32 -> 9/17/33 ms, batch-size free, so
# the admission tests below can pick deadlines between rungs exactly.
MODEL = LinearServiceModel(base_s=0.001, per_row_s=0.0, per_ef_s=0.001,
                           per_ef_row_s=0.0)


@functools.lru_cache(maxsize=None)
def _index():
    items = jnp.asarray(mips_dataset(N, D, "lognormal", seed=3))
    return IpNSW(max_degree=8, ef_construction=16, insert_batch=100).build(items)


@functools.lru_cache(maxsize=None)
def _plus_index():
    items = jnp.asarray(mips_dataset(250, D, "gaussian", seed=4))
    return IpNSWPlus(max_degree=8, ef_construction=16,
                     insert_batch=100).build(items)


def _trace(seed=5, n=24, ef=16):
    q = mips_queries(n, D, seed=11)
    return poisson_trace(q, rate_qps=400.0, seed=seed, ef=ef,
                         classes=("interactive", "standard", "relaxed"))


def _loop(index=None, ladder=LADDER, model=MODEL, k=K):
    return ServeLoop(index if index is not None else _index(),
                     ladder=ladder, clock=VirtualClock(), k=k,
                     service_model=model)


def _request(rid, q, arrival, budget, ef, klass="standard"):
    return Request(rid=rid, query=np.asarray(q, np.float32),
                   arrival_t=arrival, deadline_t=arrival + budget,
                   ef=ef, klass=klass)


# ---------------------------------------------------------------- replay pin


def test_replay_bit_identical():
    """Same arrival trace => bit-identical schedule AND results."""
    s1 = _loop().run(_trace())
    s2 = _loop().run(_trace())
    assert [(b.dispatch_t, b.finish_t, b.bucket, b.rids, b.ef_served)
            for b in s1.batches] == \
           [(b.dispatch_t, b.finish_t, b.bucket, b.rids, b.ef_served)
            for b in s2.batches]
    r1 = {r.rid: r for r in s1.responses}
    r2 = {r.rid: r for r in s2.responses}
    assert set(r1) == set(r2) == set(range(24))  # everything served, once
    for rid in r1:
        assert np.array_equal(r1[rid].ids, r2[rid].ids)
        assert np.array_equal(r1[rid].scores, r2[rid].scores)
        assert r1[rid].finish_t == r2[rid].finish_t
        assert r1[rid].ef_served == r2[rid].ef_served
    # Serving is read-only: the graph must satisfy the structural
    # invariants (core/invariants.py) after the runs exactly as on build.
    from repro.core.invariants import assert_graph_invariants

    assert_graph_invariants(_index().graph)


# ------------------------------------------------------- padding equivalence


def test_padding_equivalence_vs_direct_search():
    """A query answered inside a padded bucket returns exactly the
    ids/scores of an unpadded ``search`` at the same ef."""
    idx = _index()
    q = mips_queries(3, D, seed=21)
    reqs = [_request(i, q[i], 0.0, 10.0, 16, "relaxed") for i in range(3)]
    stats = _loop().run(reqs)
    assert len(stats.responses) == 3
    # 3 requests pad into the 4-wide bucket at the requested ef
    assert stats.batches[0].bucket == Bucket(4, 16)
    direct = idx.search(jnp.asarray(q), k=K, ef=16)
    for r in stats.responses:
        assert r.ef_served == 16
        assert np.array_equal(r.ids, np.asarray(direct.ids)[r.rid])
        assert np.array_equal(r.scores, np.asarray(direct.scores)[r.rid])
    # ...and against a true solo (B=1) search: ids stay bit-identical;
    # scores only to fp tolerance (XLA lowers a single-row score as a
    # matrix-vector product whose reduction order differs by 1 ulp from the
    # batched matmul — the walk's decisions survive, the last bit doesn't).
    solo = idx.search(jnp.asarray(q[:1]), k=K, ef=16)
    r0 = next(r for r in stats.responses if r.rid == 0)
    assert np.array_equal(r0.ids, np.asarray(solo.ids)[0])
    assert np.allclose(r0.scores, np.asarray(solo.scores)[0], rtol=1e-6)


def test_padding_equivalence_valid_mask_direct():
    """The underlying ``valid=`` contract on the index entry point: pad rows
    return ids=-1 at zero evals, live rows are bit-identical."""
    idx = _index()
    q = np.zeros((4, D), np.float32)
    live = mips_queries(2, D, seed=33)
    q[:2] = live
    valid = np.array([True, True, False, False])
    r_pad = idx.search(jnp.asarray(q), k=K, ef=16, valid=jnp.asarray(valid))
    r_solo = idx.search(jnp.asarray(live), k=K, ef=16)
    assert np.array_equal(np.asarray(r_pad.ids)[:2], np.asarray(r_solo.ids))
    assert np.array_equal(np.asarray(r_pad.scores)[:2],
                          np.asarray(r_solo.scores))
    assert np.all(np.asarray(r_pad.ids)[2:] == -1)
    assert np.all(np.asarray(r_pad.evals)[2:] == 0)


def test_padding_equivalence_pallas_backend():
    """Same pin through the fused-kernel walk (interpret mode off-TPU)."""
    idx = _index()
    live = mips_queries(2, D, seed=41)
    q = np.zeros((4, D), np.float32)
    q[:2] = live
    valid = jnp.asarray(np.array([True, True, False, False]))
    r_pad = idx.search(jnp.asarray(q), k=K, ef=8, valid=valid,
                       backend="pallas")
    r_solo = idx.search(jnp.asarray(live), k=K, ef=8, backend="pallas")
    assert np.array_equal(np.asarray(r_pad.ids)[:2], np.asarray(r_solo.ids))
    assert np.all(np.asarray(r_pad.ids)[2:] == -1)


def test_padding_equivalence_ipnsw_plus():
    """The dual-graph index serves through the same bucket machinery and
    obeys the same padding pin (valid= masks BOTH walks)."""
    idx = _plus_index()
    q = mips_queries(3, D, seed=51)
    reqs = [_request(i, q[i], 0.0, 10.0, 16, "relaxed") for i in range(3)]
    stats = _loop(index=idx).run(reqs)
    direct = idx.search(jnp.asarray(q), k=K, ef=16)
    assert len(stats.responses) == 3
    for r in stats.responses:
        assert np.array_equal(r.ids, np.asarray(direct.ids)[r.rid])
        assert np.array_equal(r.scores, np.asarray(direct.scores)[r.rid])


# ------------------------------------------------------- deadline admission


def test_largest_fitting_ef_is_served():
    """With slack for the top rung, the request's full dial is honored."""
    stats = _loop().run([_request(0, mips_queries(1, D, seed=61)[0],
                                  0.0, 1.0, 32, "relaxed")])
    (r,) = stats.responses
    assert r.ef_served == 32 and not r.degraded and r.deadline_met


def test_degrade_to_smaller_ef_before_reject():
    """ef 32 costs 33ms; a 20ms budget fits ef 16 (17ms) — the scheduler
    degrades one rung instead of rejecting or missing."""
    stats = _loop().run([_request(0, mips_queries(1, D, seed=62)[0],
                                  0.0, 0.020, 32)])
    (r,) = stats.responses
    assert r.ef_served == 16 and r.degraded and r.deadline_met


def test_impossible_deadline_served_late_at_floor_not_rejected():
    """Nothing fits a 2ms budget (floor ef 8 costs 9ms): the request is
    still served — at the ladder floor, late — never dropped."""
    stats = _loop().run([_request(0, mips_queries(1, D, seed=63)[0],
                                  0.0, 0.002, 32)])
    (r,) = stats.responses
    assert r.ef_served == 8 and r.degraded and not r.deadline_met


def test_fifo_within_deadline_class():
    """Same class (same budget) => deadline order == arrival order, so the
    batch composition is FIFO chunks of the arrival sequence."""
    q = mips_queries(5, D, seed=64)
    reqs = [_request(i, q[i], 0.001 * i, 1.0, 8) for i in range(5)]
    ladder = BucketLadder(batches=(2,), efs=(8,))
    stats = _loop(ladder=ladder).run(reqs)
    assert [b.rids for b in stats.batches] == [(0, 1), (2, 3), (4,)]


def test_earlier_deadline_preempts_later_arrival_order():
    """Across classes the queue is deadline-ordered: an interactive request
    (rid 2) queued behind two relaxed ones jumps to the first batch."""
    q = mips_queries(3, D, seed=65)
    reqs = [_request(0, q[0], 0.0, 1.000, 8, "relaxed"),
            _request(1, q[1], 0.0, 1.000, 8, "relaxed"),
            _request(2, q[2], 0.0, 0.020, 8, "interactive")]
    ladder = BucketLadder(batches=(2,), efs=(8,))
    stats = _loop(ladder=ladder).run(reqs)
    assert [b.rids for b in stats.batches] == [(2, 0), (1,)]


def test_never_rejects_under_burst():
    """A burst far above capacity degrades and runs late but every request
    is answered exactly once."""
    n = 20
    q = mips_queries(n, D, seed=66)
    reqs = [_request(i, q[i], 0.0, 0.005, 32, "interactive")
            for i in range(n)]
    stats = _loop().run(reqs)
    assert sorted(r.rid for r in stats.responses) == list(range(n))


# ------------------------------------------------------------- recompiles


def test_zero_steady_state_recompiles():
    """One compile per ladder bucket at warmup; traffic — including a
    second trace on the same loop — triggers none (the serve.py smoke
    meter for bucket-ladder regressions)."""
    loop = _loop()
    s1 = loop.run(_trace())
    assert s1.recompiles_warmup == len(LADDER.buckets())
    assert s1.recompiles_steady == 0
    s2 = loop.run(_trace(seed=99))
    assert s2.recompiles_warmup == len(LADDER.buckets())
    assert s2.recompiles_steady == 0


# ------------------------------------------------- virtual-time purity


def test_virtual_mode_never_touches_wall_clock(monkeypatch):
    """Poison serve_loop's own reference to the ``time`` module: a virtual
    run must complete without a single wall-clock call."""
    import repro.launch.serve_loop as sl

    class _Boom:
        def __getattr__(self, name):
            raise AssertionError(f"virtual serve path called time.{name}")

    monkeypatch.setattr(sl, "time", _Boom())
    stats = _loop().run(_trace(seed=7))
    assert len(stats.responses) == 24


# --------------------------------------------------------------- unit tests


def test_ladder_bucket_selection():
    ladder = BucketLadder(batches=(2, 4, 8), efs=(8, 32))
    assert ladder.batch_for(1) == 2
    assert ladder.batch_for(3) == 4
    assert ladder.batch_for(8) == 8
    with pytest.raises(ValueError):
        ladder.batch_for(9)
    assert ladder.ef_pref(64) == 32
    assert ladder.ef_pref(32) == 32
    assert ladder.ef_pref(10) == 8
    assert ladder.ef_pref(4) == 8  # below every rung -> floor
    assert len(ladder.buckets()) == 6


def test_ladder_validation():
    with pytest.raises(ValueError):
        BucketLadder(batches=(4, 2), efs=(8,))
    with pytest.raises(ValueError):
        BucketLadder(batches=(2,), efs=(8, 8))
    with pytest.raises(ValueError):
        BucketLadder(batches=(), efs=(8,))


def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c.now() == 0.0
    c.sleep_until(1.5)
    assert c.now() == 1.5
    c.sleep_until(1.0)  # never goes backwards
    assert c.now() == 1.5


def test_poisson_trace_deterministic():
    q = mips_queries(8, D, seed=71)
    t1 = poisson_trace(q, rate_qps=100.0, seed=3,
                       classes=("interactive", "relaxed"))
    t2 = poisson_trace(q, rate_qps=100.0, seed=3,
                       classes=("interactive", "relaxed"))
    assert [(r.rid, r.arrival_t, r.deadline_t, r.klass) for r in t1] == \
           [(r.rid, r.arrival_t, r.deadline_t, r.klass) for r in t2]
    assert all(a.arrival_t < b.arrival_t for a, b in zip(t1, t1[1:]))


def test_executor_rejects_unbuilt_and_unknown_index():
    with pytest.raises(TypeError):
        BucketExecutor(object(), LADDER)


def test_service_model_is_pure():
    m = LinearServiceModel(base_s=1.0, per_row_s=0.1, per_ef_s=0.01,
                           per_ef_row_s=0.001)
    b = Bucket(4, 16)
    assert m.service_s(b) == m.service_s(b) == 1.0 + 0.4 + 0.16 + 0.064


# ------------------------------------------------------ wall-clock smoke


@pytest.mark.slow
@pytest.mark.skipif(QUICK, reason="quick tier is purely virtual-time")
def test_wall_clock_smoke():
    """The same loop serves under real time (finish stamps come from the
    wall, not the model).  Timing is asserted only loosely — ordering and
    completeness, nothing wall-clock-flaky."""
    q = mips_queries(6, D, seed=81)
    reqs = poisson_trace(q, rate_qps=2000.0, seed=4, ef=16,
                         classes=("relaxed",))
    loop = ServeLoop(_index(), ladder=LADDER, clock=WallClock(), k=K,
                     service_model=MODEL)
    stats = loop.run(reqs)
    assert sorted(r.rid for r in stats.responses) == list(range(6))
    for r in stats.responses:
        assert r.finish_t >= r.dispatch_t >= 0.0
    assert stats.recompiles_steady == 0
