"""Build-backend parity: the scan build must be BIT-IDENTICAL to the host
loop for the same batch schedule (DESIGN.md §6).

The sizes are chosen so the schedule has a ragged tail batch — the scan
backend pads and masks it, which is exactly the path that must not perturb
the committed graph.  REPRO_TEST_QUICK=1 shrinks the datasets (consistent
with REPRO_BENCH_QUICK for benchmarks).
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import IpNSW, IpNSWPlus
from repro.core.build import batch_schedule, build_graph, commit_batch
from repro.core.graph import GraphIndex, empty_graph
from repro.core.hnsw import HierarchicalIpNSW
from repro.data import mips_dataset

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

N = 460 if QUICK else 900   # not a multiple of insert_batch => ragged tail
D = 16
BATCH = 128
PROFILES = ("gaussian", "lognormal")


def _items(profile):
    return jnp.asarray(mips_dataset(N, D, profile=profile, seed=11))


def _assert_graphs_identical(
    g_host: GraphIndex, g_scan: GraphIndex, check_invariants: bool = True
):
    from repro.core.invariants import assert_graph_invariants

    assert np.array_equal(np.asarray(g_host.adj), np.asarray(g_scan.adj))
    assert int(g_host.size) == int(g_scan.size)
    assert int(g_host.entry) == int(g_scan.entry)
    # Every freshly built graph must satisfy the structural invariants the
    # mutation layer later relies on (core/invariants.py I1-I6).  Tests that
    # commit fabricated random neighbor lists (which may contain self-loops
    # no real find_neighbors would produce) opt out.
    if check_invariants:
        assert_graph_invariants(g_host, name="host")
        assert_graph_invariants(g_scan, name="scan")


@pytest.mark.parametrize("profile", PROFILES)
def test_ipnsw_scan_build_bit_identical(profile):
    items = _items(profile)
    kw = dict(max_degree=8, ef_construction=16, insert_batch=BATCH)
    host = IpNSW(**kw).build(items)
    scan = IpNSW(**kw, build_backend="scan").build(items)
    _assert_graphs_identical(host.graph, scan.graph)


@pytest.mark.parametrize("profile", PROFILES)
def test_ipnsw_plus_scan_build_bit_identical(profile):
    items = _items(profile)
    kw = dict(
        max_degree=8, ef_construction=16, ang_degree=6, ang_ef=8,
        insert_batch=BATCH,
    )
    host = IpNSWPlus(**kw).build(items)
    scan = IpNSWPlus(**kw, build_backend="scan").build(items)
    _assert_graphs_identical(host.ip_graph, scan.ip_graph)
    _assert_graphs_identical(host.ang_graph, scan.ang_graph)


def test_scan_build_no_reverse_links_bit_identical():
    """The printed-Algorithm-2 variant (directed edges only) goes through a
    different commit path — pin it too."""
    items = _items("gaussian")
    kw = dict(max_degree=8, ef_construction=16, insert_batch=BATCH,
              reverse_links=False)
    g_host = build_graph(items, **kw)
    g_scan = build_graph(items, **kw, build_backend="scan")
    _assert_graphs_identical(g_host, g_scan)


def test_batch_schedule_partitions_ids():
    """Every id is inserted exactly once: bootstrap prefix + valid batch ids
    partition range(n); pad slots are clamped in-range and invalid."""
    for n in (5, 128, 129, 460, 900, 1024):
        first, ids, valid = batch_schedule(n, BATCH)
        seen = list(range(first)) + sorted(ids[valid].tolist())
        assert seen == list(range(n))
        if ids.shape[0]:
            assert ids.min() >= 0 and ids.max() <= n - 1
            assert ids.shape[1:] == (BATCH,)


def test_commit_batch_padded_equals_ragged():
    """A padded+masked commit writes the same graph as the ragged commit."""
    rng = np.random.default_rng(3)
    items = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    norms = jnp.linalg.norm(items, axis=-1)
    base = empty_graph(items, 4)
    base = commit_batch(
        base, jnp.arange(32, dtype=jnp.int32),
        jnp.asarray(rng.integers(0, 32, (32, 4)).astype(np.int32)),
        jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32)),
        norms,
    )
    bids = jnp.arange(32, 37, dtype=jnp.int32)
    nbr = jnp.asarray(rng.integers(0, 32, (5, 4)).astype(np.int32))
    sc = jnp.asarray(rng.normal(size=(5, 4)).astype(np.float32))
    ragged = commit_batch(base, bids, nbr, sc, norms)

    pad = 3
    bids_p = jnp.concatenate([bids, jnp.full((pad,), 36, jnp.int32)])
    nbr_p = jnp.concatenate([nbr, jnp.full((pad, 4), -1, jnp.int32)])
    sc_p = jnp.concatenate([sc, jnp.full((pad, 4), -np.inf, jnp.float32)])
    valid = jnp.concatenate([jnp.ones(5, bool), jnp.zeros(pad, bool)])
    padded = commit_batch(base, bids_p, nbr_p, sc_p, norms, valid=valid)
    _assert_graphs_identical(ragged, padded, check_invariants=False)


def test_scan_build_rejects_neighbor_fn():
    items = _items("gaussian")
    with pytest.raises(ValueError, match="neighbor_fn"):
        build_graph(items, insert_batch=BATCH, build_backend="scan",
                    neighbor_fn=lambda g, b: None)
    with pytest.raises(ValueError, match="build_backend"):
        build_graph(items, build_backend="nope")


# ---------------------------------------------------------------------------
# commit-backend axis: the fused commit-merge kernel must commit the SAME
# graph as the sort-based reference on both build drivers (DESIGN.md §7).
# Sizes are smaller than the host/scan axis above because the pallas commit
# runs in interpret mode off-TPU.
# ---------------------------------------------------------------------------

NC = 220 if QUICK else 300
CB_BATCH = 64


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("build_backend", ("host", "scan"))
def test_commit_backend_bit_identical(profile, build_backend):
    items = jnp.asarray(mips_dataset(NC, D, profile=profile, seed=7))
    kw = dict(max_degree=8, ef_construction=16, insert_batch=CB_BATCH,
              build_backend=build_backend)
    ref = build_graph(items, **kw)
    pal = build_graph(items, **kw, commit_backend="pallas")
    _assert_graphs_identical(ref, pal)
    assert float(ref.entry_norm) == float(pal.entry_norm)


@pytest.mark.parametrize("build_backend", ("host", "scan"))
def test_commit_tile_bit_identical_across_drivers(build_backend):
    """The tiled commit grid (DESIGN.md §7) is pure geometry: host and scan
    builds at a non-default tile — including the auto-planned one — must
    commit the exact graph the untiled reference does."""
    items = jnp.asarray(mips_dataset(NC, D, profile="lognormal", seed=7))
    kw = dict(max_degree=8, ef_construction=16, insert_batch=CB_BATCH,
              build_backend=build_backend)
    ref = build_graph(items, **kw)
    for tile in (5, "auto"):
        tiled = build_graph(items, **kw, commit_backend="pallas",
                            commit_tile=tile)
        _assert_graphs_identical(ref, tiled)


def test_commit_backend_bit_identical_plus_scan():
    """ip-NSW+ scan build: BOTH carried graphs (angular + ip) must match
    across commit backends — the §4.2 interleaving amplifies any drift."""
    items = _items("gaussian")[:NC]
    kw = dict(max_degree=8, ef_construction=16, ang_degree=6, ang_ef=8,
              insert_batch=CB_BATCH, build_backend="scan")
    ref = IpNSWPlus(**kw).build(items)
    pal = IpNSWPlus(**kw, commit_backend="pallas").build(items)
    _assert_graphs_identical(ref.ip_graph, pal.ip_graph)
    _assert_graphs_identical(ref.ang_graph, pal.ang_graph)


def test_entry_carry_matches_full_argmax():
    """commit_batch advances the entry with an O(B) carried compare; pin it
    against the historical full [N] masked argmax on both drivers, plus the
    carried norm against the entry's actual norm."""
    for profile in PROFILES:
        items = _items(profile)
        for bb in ("host", "scan"):
            g = build_graph(items, max_degree=8, ef_construction=16,
                            insert_batch=BATCH, build_backend=bb)
            norms = np.linalg.norm(np.asarray(g.items), axis=-1)
            inserted = np.arange(norms.shape[0]) < int(g.size)
            full = int(np.argmax(np.where(inserted, norms, -np.inf)))
            assert int(g.entry) == full
            assert float(g.entry_norm) == norms[int(g.entry)]


def test_build_graph_rejects_unknown_backends_eagerly():
    """Typo'd backends must fail before any build work, not mid-trace."""
    items = _items("gaussian")
    with pytest.raises(ValueError, match="backend"):
        build_graph(items, backend="cuda")
    with pytest.raises(ValueError, match="commit_backend"):
        build_graph(items, commit_backend="nope")
    with pytest.raises(ValueError, match="backend"):
        IpNSWPlus(backend="cuda").build(items)
    with pytest.raises(ValueError, match="commit_backend"):
        IpNSWPlus(commit_backend="nope").build(items)


def test_hierarchical_scan_build_searches():
    """HierarchicalIpNSW threads build_backend through every level; the
    level graphs are scan-built and search still returns sane results."""
    items = _items("gaussian")
    kw = dict(max_degree=8, ef_construction=16, insert_batch=BATCH, seed=0)
    host = HierarchicalIpNSW(**kw).build(items)
    scan = HierarchicalIpNSW(**kw, build_backend="scan").build(items)
    assert len(host.levels) == len(scan.levels)
    for gh, gs in zip(host.levels, scan.levels):
        _assert_graphs_identical(gh, gs)
    q = jnp.asarray(mips_dataset(8, D, profile="gaussian", seed=5))
    rh = host.search(q, k=5, ef=16)
    rs = scan.search(q, k=5, ef=16)
    assert np.array_equal(np.asarray(rh.ids), np.asarray(rs.ids))
