"""Streaming-mutation suite (core/mutation.py + the churn serving path).

The contract under test, in rough order of severity:

  * slot discipline — deletes tombstone (rows stay routable), upserts reuse
    tombstoned slots FIFO before headroom, exhaustion refuses BEFORE
    mutating anything (graceful error, never corruption);
  * search hygiene — a tombstoned id never appears in results, on any
    (backend, storage) axis, including the sharded merge (the interior-
    delete regression: ``count`` only masks the zero-pad tail);
  * graph invariants — core/invariants.py holds after every mutation,
    including entry re-seat when the entry vertex itself dies;
  * churn end-to-end — the ISSUE acceptance scenario: a seeded ChurnTrace
    with >=20% turnover plus one adversarial hub-kill, replayed through the
    continuous-batching loop on a VirtualClock: zero rejected requests,
    zero steady-state recompiles, bit-identical replay, and post-full-relink
    recall@10 within 0.02 of a fresh rebuild of the same catalog — on both
    norm profiles;
  * determinism — the whole mutation layer is a pure function of its seeds,
    and ref-vs-pallas walk backends mutate bit-identically.

The property test runs under hypothesis when installed, else the offline
``_propcheck`` fallback (same API, deterministic draws).
"""
import functools
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback
    from _propcheck import given, settings, st

from repro.core import (
    ChurnTrace,
    IpNSW,
    IpNSWPlus,
    MutableIndex,
    check_graph_invariants,
)
from repro.data import mips_dataset, mips_queries
from repro.launch.serve_loop import (
    BucketLadder,
    LinearServiceModel,
    ServeLoop,
    VirtualClock,
    poisson_trace,
)

QUICK = os.environ.get("REPRO_TEST_QUICK", "0") == "1"

N, D, K = 300, 16, 10
LADDER = BucketLadder(batches=(4, 8), efs=(16, 32))
MODEL = LinearServiceModel()


def _items(profile="gaussian", n=N, seed=0):
    return mips_dataset(n, D, profile, seed=seed)


def _mutable(profile="gaussian", *, plus=False, capacity=N + 128, seed=0,
             relink_threshold=0.3, **kw):
    cls = IpNSWPlus if plus else IpNSW
    idx = cls(max_degree=8, ef_construction=32, insert_batch=100,
              **kw).build(jnp.asarray(_items(profile, seed=seed)))
    return MutableIndex(idx, capacity=capacity, mutation_batch=16,
                        relink_threshold=relink_threshold)


def _exact_live_topk(queries, items, live, k=K):
    scores = np.asarray(queries) @ np.asarray(items).T
    scores = np.where(np.asarray(live, bool)[None, : items.shape[0]],
                      scores, -np.inf)
    return np.argsort(-scores, axis=1, kind="stable")[:, :k]


def _recall(ids, gt):
    ids = np.asarray(ids)
    hits = sum(len(set(ids[i][ids[i] >= 0]) & set(gt[i]))
               for i in range(len(gt)))
    return hits / (gt.shape[0] * gt.shape[1])


def _assert_clean(m, max_dead=1.0):
    errs = m.check_invariants(max_dead_edge_frac=max_dead)
    assert not errs, "\n".join(errs)


# ------------------------------------------------------------ slot discipline


def test_upsert_appends_to_headroom_then_search_finds_it():
    m = _mutable()
    new = _items(n=12, seed=7) + 3.0  # large-IP outliers: must surface
    slots = m.upsert(new)
    assert list(slots) == list(range(N, N + 12))
    _assert_clean(m)
    r = m.search(jnp.asarray(new), k=1, ef=64)
    assert set(np.asarray(r.ids).ravel()) <= set(slots.tolist())


def test_delete_then_upsert_reuses_slots_fifo():
    m = _mutable()
    m.delete([5, 9])
    m.delete([200])
    slots = m.upsert(_items(n=4, seed=8))
    # FIFO by deletion time, then fresh headroom.
    assert list(slots) == [5, 9, 200, N]
    assert m._live_host[[5, 9, 200, N]].all()
    _assert_clean(m)


def test_deleted_ids_never_surface_any_axis():
    dead = list(range(40, 80))
    queries = jnp.asarray(mips_queries(16, D, seed=3))
    for plus in (False, True):
        for storage in ("f32", "int8"):
            m = _mutable(plus=plus, storage=storage)
            m.delete(dead)
            _assert_clean(m)
            for backend in ("reference", "pallas"):
                r = m.search(queries, k=K, ef=64, backend=backend)
                ids = np.asarray(r.ids)
                assert not (set(ids.ravel()) & set(dead)), (plus, storage,
                                                            backend)


def test_free_slot_exhaustion_is_graceful_not_corrupting():
    m = _mutable(capacity=N + 16)
    adj_before = np.asarray(m.graph.adj).copy()
    live_before = m._live_host.copy()
    with pytest.raises(RuntimeError, match="free-slot pool exhausted"):
        m.upsert(_items(n=17, seed=9))
    # Refused BEFORE touching device state: nothing changed.
    assert np.array_equal(np.asarray(m.graph.adj), adj_before)
    assert np.array_equal(m._live_host, live_before)
    _assert_clean(m)
    # The pool still works at the boundary.
    slots = m.upsert(_items(n=16, seed=9))
    assert len(slots) == 16
    _assert_clean(m)
    with pytest.raises(RuntimeError):
        m.upsert(_items(n=1, seed=10))


def test_delete_validation():
    m = _mutable()
    with pytest.raises(ValueError, match="used slots"):
        m.delete([N + 5])
    m.delete([3])
    with pytest.raises(ValueError, match="already tombstoned"):
        m.delete([3])
    with pytest.raises(RuntimeError, match="entire catalog"):
        m.delete(m.live_ids())  # would leave none live


def test_entry_reseat_when_entry_dies():
    m = _mutable()
    entry = int(m.graph.entry)
    m.delete([entry])
    assert int(m.graph.entry) != entry
    assert m._live_host[int(m.graph.entry)]
    _assert_clean(m)  # I4: entry must be live
    r = m.search(jnp.asarray(mips_queries(8, D, seed=4)), k=K, ef=64)
    assert (np.asarray(r.ids) != entry).all()


# --------------------------------------------------------------- repair layer


def test_relink_pays_down_debt_and_respects_budget():
    m = _mutable()
    rng = np.random.default_rng(0)
    m.delete(rng.choice(N, size=90, replace=False))
    debt = m.relink_debt()
    assert debt > 0
    assert m.relink(5) == 5          # budget respected
    assert m.relink_debt() < debt
    while m.relink_debt():
        m.relink(64)
    _assert_clean(m, max_dead=0.35)  # I6 under the default threshold


def test_hub_kill_recovers_after_relink():
    m = _mutable("lognormal", seed=2)
    queries = mips_queries(24, D, seed=5)
    killed = m.kill_hubs(6)
    assert len(killed) == 6 and not m._live_host[killed].any()
    _assert_clean(m)
    while m.relink_debt():
        m.relink(64)
    _assert_clean(m, max_dead=0.35)
    gt = _exact_live_topk(queries, np.asarray(m.graph.items), m._live_host)
    rec = _recall(m.search(jnp.asarray(queries), k=K, ef=64).ids, gt)
    compact = np.asarray(m.graph.items)[m.live_ids()]
    fresh = IpNSW(max_degree=8, ef_construction=32,
                  insert_batch=100).build(jnp.asarray(compact))
    gt_f = np.argsort(-(queries @ compact.T), axis=1, kind="stable")[:, :K]
    rec_fresh = _recall(fresh.search(jnp.asarray(queries), k=K, ef=64).ids,
                        gt_f)
    assert rec >= rec_fresh - 0.02, (rec, rec_fresh)


# ---------------------------------------------------- int8 store stays in sync


def test_int8_store_tracks_mutations_exactly():
    from repro.core.storage import quantize_items

    m = _mutable(storage="int8")
    m.delete(np.arange(10, 40))
    m.upsert(_items(n=20, seed=11))
    # The cached store must equal a from-scratch quantization of the current
    # item matrix, bit for bit — the strongest possible sync pin.
    ref = quantize_items(m.graph.items)
    assert np.array_equal(np.asarray(m.index.store.codes),
                          np.asarray(ref.codes))
    assert np.array_equal(np.asarray(m.index.store.scales),
                          np.asarray(ref.scales))


# ------------------------------------------------- backend axis bit-identical


def test_mutation_bit_identical_reference_vs_pallas():
    queries = jnp.asarray(mips_queries(16, D, seed=6))
    results = {}
    for backend in ("reference", "pallas"):
        m = _mutable("lognormal", seed=3, backend=backend)
        rng = np.random.default_rng(1)
        m.delete(rng.choice(N, size=40, replace=False))
        m.upsert(_items(n=24, seed=12))
        while m.relink_debt():
            m.relink(64)
        r = m.search(queries, k=K, ef=64)
        results[backend] = (np.asarray(m.graph.adj), np.asarray(r.ids))
    assert np.array_equal(results["reference"][0], results["pallas"][0])
    assert np.array_equal(results["reference"][1], results["pallas"][1])


# ------------------------------------------- sharded interior-delete regression


def test_sharded_interior_delete_cannot_surface():
    """``count`` masks only the zero-pad tail; an interior tombstone must be
    dropped by the ``live`` mask — in the local walks AND the merge."""
    from repro.core.distributed import build_sharded, sharded_search_reference

    items = _items(n=128, seed=13)
    index = build_sharded(jnp.asarray(items), 2, plus=False,
                          max_degree=8, ef_construction=16, insert_batch=32)
    # A query aimed straight at an interior row of shard 0.
    target = 17
    queries = jnp.asarray(items[target][None] * 4.0)
    ids, _, _ = sharded_search_reference(index, queries, k=5, plus=False)
    assert target in np.asarray(ids)[0], "target must win before the delete"

    nloc = index.ip.adj.shape[1]
    live = np.ones((2, nloc), bool)
    live[0, target] = False
    dead_index = index._replace(live=jnp.asarray(live))
    ids2, scores2, _ = sharded_search_reference(dead_index, queries, k=5,
                                                plus=False)
    ids2 = np.asarray(ids2)[0]
    assert target not in ids2, "interior tombstone leaked through the merge"
    assert (ids2 >= 0).all() and np.isfinite(np.asarray(scores2)).all()


def test_sharded_banded_churn_keeps_invariants_and_recall():
    """ISSUE-10 sharded-churn acceptance: upserts and deletes on a
    norm-banded ShardedMutable — including killing an entire top band's
    hubs — keep the per-band I1–I6 invariants green, keep tombstoned gids
    and widened-norm items consistent with the routing bound, and land
    post-relink routed recall@10 within 0.02 of a fresh banded rebuild of
    the same live catalog."""
    from repro.core.distributed import (
        ShardedMutable, build_sharded, sharded_search_reference,
    )

    p = 4
    items = _items("lognormal", n=256, seed=7)
    queries = jnp.asarray(mips_queries(32, D, seed=77))
    sm = ShardedMutable(items, p, plus=False, headroom=64, max_degree=8,
                        ef_construction=16, insert_batch=64)
    assert sm.check_invariants() == []

    def routed(storage="f32"):
        snap = sm.snapshot(storage=storage)
        return sharded_search_reference(
            snap, queries, k=K, ef=64, plus=False, route="upper_bound",
            storage=storage, return_stats=True,
        )

    def live_recall(ids):
        gids, live_items = sm.live_items()
        gt_rows = np.argsort(
            -(np.asarray(queries) @ live_items.T), axis=1, kind="stable"
        )[:, :K]
        gt = gids[gt_rows]          # map row positions back to global ids
        return _recall(np.asarray(ids), gt)

    base = live_recall(routed()[0])

    # churn: delete a third of the catalog, upsert replacements whose norms
    # straddle the band edges — incl. outliers ABOVE band 0's max, which
    # must widen its recorded bound, not break it
    rng = np.random.default_rng(3)
    sm.delete(rng.choice(sm.live_gids(), size=80, replace=False))
    fresh_items = _items("lognormal", n=96, seed=8).copy()
    fresh_items[:4] *= 10.0  # norm outliers routed to band 0, widening it
    new_gids = sm.upsert(fresh_items)
    assert sm.check_invariants() == []
    assert len(set(new_gids.tolist())) == 96

    # the routing bound survives churn: every band's live max norm is
    # bounded by its recorded max_norm
    snap = sm.snapshot()
    norms = np.linalg.norm(np.asarray(snap.ip.items), axis=-1)
    live = np.asarray(snap.live, bool)
    for s in range(p):
        if live[s].any():
            assert norms[s][live[s]].max() <= float(snap.max_norm[s]) + 1e-5

    # adversarial: tombstone ALL of the top band's hubs (all but one member)
    killed = sm.kill_hubs(0, k=sm.capacity)
    assert len(killed) > 0
    assert sm.check_invariants() == []

    # full repair, then the acceptance bar vs a fresh banded rebuild
    while sm.relink_debt():
        sm.relink(64)
    assert sm.check_invariants() == []
    ids_post, _, _, stats = routed()
    # no dead gid may surface
    dead = set(map(int, killed)) | {
        int(g) for g in range(256) if int(g) not in set(sm.live_gids())
    }
    assert not (set(np.asarray(ids_post).ravel().tolist()) - {-1}) & dead
    rec_post = live_recall(ids_post)

    gids, live_items = sm.live_items()
    fresh = build_sharded(jnp.asarray(live_items), p, plus=False,
                          partition="norm_bands", max_degree=8,
                          ef_construction=16, insert_batch=64)
    ids_f, _, _ = sharded_search_reference(
        fresh, queries, k=K, ef=64, plus=False, route="upper_bound")
    gt_rows = np.argsort(-(np.asarray(queries) @ live_items.T),
                         axis=1, kind="stable")[:, :K]
    rec_fresh = _recall(np.asarray(ids_f), gt_rows)
    assert rec_post >= rec_fresh - 0.02, (rec_post, rec_fresh, base)


# --------------------------------------------------------- churn end-to-end


def _run_churn_loop(profile, seed=0):
    m = _mutable(profile, seed=seed, capacity=N + 128)
    queries = mips_queries(48, D, seed=20 + seed)
    trace = poisson_trace(queries, rate_qps=800.0, seed=seed, ef=32,
                          classes=("standard", "relaxed"))
    dur = max(r.arrival_t for r in trace) + 0.01
    churn = ChurnTrace.generate(
        n_items=N, dim=D, duration_s=dur, turnover=0.25, batch=16,
        seed=seed + 1, profile=profile, hub_kill_at=dur / 2, hub_kill_k=4,
        relink_every=dur / 3, relink_budget=32,
    )
    loop = ServeLoop(m, ladder=LADDER, clock=VirtualClock(), k=K,
                     service_model=MODEL, assert_invariants=True)
    stats = loop.run(trace, churn=churn)
    return m, stats, queries


@pytest.mark.parametrize("profile", ["gaussian", "lognormal"])
def test_churn_trace_through_serve_loop_end_to_end(profile):
    """The ISSUE acceptance scenario (>=20% turnover + one hub-kill)."""
    m, stats, queries = _run_churn_loop(profile)
    s = stats.summary()
    assert s["served"] == 48 and s["rejected"] == 0
    assert s["recompiles_steady"] == 0, "churn must not break compile-once"
    assert s["mutation_events"] >= 2 * int(0.25 * N / 16) + 1
    _assert_clean(m)

    # Full repair, then the recall floor vs a fresh rebuild of the same
    # (post-churn) catalog.
    while m.relink_debt():
        m.relink(64)
    _assert_clean(m, max_dead=0.35)
    gt = _exact_live_topk(queries, np.asarray(m.graph.items), m._live_host)
    rec = _recall(m.search(jnp.asarray(queries), k=K, ef=64).ids, gt)
    compact = np.asarray(m.graph.items)[m.live_ids()]
    fresh = IpNSW(max_degree=8, ef_construction=32,
                  insert_batch=100).build(jnp.asarray(compact))
    gt_f = np.argsort(-(queries @ compact.T), axis=1, kind="stable")[:, :K]
    rec_fresh = _recall(fresh.search(jnp.asarray(queries), k=K, ef=64).ids,
                        gt_f)
    assert rec >= rec_fresh - 0.02, (profile, rec, rec_fresh)


def test_churn_replay_bit_identical():
    a = _run_churn_loop("gaussian")[1]
    b = _run_churn_loop("gaussian")[1]
    assert [r.rid for r in a.responses] == [r.rid for r in b.responses]
    for ra, rb in zip(a.responses, b.responses):
        assert np.array_equal(ra.ids, rb.ids)
        assert np.array_equal(ra.scores, rb.scores)
        assert ra.dispatch_t == rb.dispatch_t and ra.finish_t == rb.finish_t
    assert [(x.bucket, x.rids) for x in a.batches] == \
           [(x.bucket, x.rids) for x in b.batches]


@pytest.mark.skipif(QUICK, reason="plus-index churn covered by the quick "
                                  "gaussian run; full tier only")
def test_churn_end_to_end_ipnsw_plus():
    m = _mutable("lognormal", plus=True, seed=4, capacity=N + 128)
    queries = mips_queries(32, D, seed=30)
    trace = poisson_trace(queries, rate_qps=800.0, seed=4, ef=32)
    dur = max(r.arrival_t for r in trace) + 0.01
    churn = ChurnTrace.generate(n_items=N, dim=D, duration_s=dur,
                                turnover=0.25, batch=16, seed=5,
                                profile="lognormal", hub_kill_at=dur / 2,
                                hub_kill_k=4)
    loop = ServeLoop(m, ladder=LADDER, clock=VirtualClock(), k=K,
                     service_model=MODEL, assert_invariants=True)
    stats = loop.run(trace, churn=churn)
    assert stats.summary()["rejected"] == 0
    assert stats.summary()["recompiles_steady"] == 0
    while m.relink_debt():
        m.relink(64)
    _assert_clean(m, max_dead=0.35)


# ------------------------------------------------------------- property test


@given(st.integers(0, 2**16), st.integers(2, 5))
@settings(max_examples=4 if QUICK else 10, deadline=None)
def test_property_interleaved_churn_meets_recall_floor(seed, n_ops):
    """Any seeded interleaving of upserts/deletes, followed by a full
    relink, keeps invariants and lands within 0.02 of a fresh rebuild —
    on both norm profiles."""
    rng = np.random.default_rng(seed)
    profile = ("gaussian", "lognormal")[seed % 2]
    # 64 queries and ef=96 on both sides: enough signal that the 0.02
    # bound tests graph quality, not 10-result sampling noise.
    queries = mips_queries(64, D, seed=seed % 97)
    # "Full relink" here means repairing every node with ANY dead out-edge
    # (threshold ~0), so the floor comparison isn't at the mercy of mildly
    # rotted rows the default 0.3 threshold deliberately leaves alone.
    m = _mutable(profile, seed=seed % 7, relink_threshold=1e-9)
    for op in range(n_ops):
        if rng.random() < 0.5:
            pool = m.live_ids()
            take = int(rng.integers(1, 25))
            take = min(take, len(pool) - 1)
            if take > 0:
                m.delete(rng.choice(pool, size=take, replace=False))
        else:
            m.upsert(mips_dataset(int(rng.integers(1, 25)), D, profile,
                                  seed=int(rng.integers(0, 2**31))))
    while m.relink_debt():
        m.relink(64)
    _assert_clean(m, max_dead=0.35)
    gt = _exact_live_topk(queries, np.asarray(m.graph.items), m._live_host)
    rec = _recall(m.search(jnp.asarray(queries), k=K, ef=96).ids, gt)
    compact = np.asarray(m.graph.items)[m.live_ids()]
    fresh = IpNSW(max_degree=8, ef_construction=32,
                  insert_batch=100).build(jnp.asarray(compact))
    gt_f = np.argsort(-(queries @ compact.T), axis=1, kind="stable")[:, :K]
    rec_fresh = _recall(fresh.search(jnp.asarray(queries), k=K, ef=96).ids,
                        gt_f)
    # 0.03 = the acceptance budget (0.02) plus ~1 sigma of two-sample
    # measurement noise at 64 queries x k=10 — arbitrary hypothesis draws
    # must not flake on sampling tails.  The exact 0.02 bar is pinned by
    # the deterministic end-to-end test above and the bench=churn CI gate.
    assert rec >= rec_fresh - 0.03, (seed, profile, rec, rec_fresh)


# ------------------------------------------------------------- guard clauses


def test_mutable_index_guards():
    with pytest.raises(TypeError):
        MutableIndex(object())
    with pytest.raises(ValueError, match="built"):
        MutableIndex(IpNSW())
    idx = IpNSW(max_degree=8, ef_construction=16).build(
        jnp.asarray(_items(n=64)))
    with pytest.raises(ValueError, match="capacity"):
        MutableIndex(idx, capacity=32)


def test_plain_index_unaffected_and_churn_requires_mutable():
    idx = IpNSW(max_degree=8, ef_construction=16).build(
        jnp.asarray(_items(n=64)))
    assert not check_graph_invariants(idx.graph)
    loop = ServeLoop(idx, ladder=LADDER, clock=VirtualClock(), k=K,
                     service_model=MODEL)
    trace = poisson_trace(mips_queries(8, D, seed=1), rate_qps=500.0,
                          seed=1, ef=32)
    churn = ChurnTrace.generate(n_items=64, dim=D, duration_s=0.1,
                                turnover=0.2, batch=8)
    with pytest.raises(TypeError, match="MutableIndex"):
        loop.run(trace, churn=churn)
    stats = loop.run(trace)
    assert stats.health is None and stats.mutation_events == 0
    assert stats.summary()["rejected"] == 0
