"""End-to-end dry-run deliverable test: one real cell is lowered + compiled
on the production single-pod mesh (512 forced host devices, subprocess so
the main test process keeps 1 device), then the roofline analyzer consumes
its artifacts."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_compiles_and_roofline_analyzes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "sasrec", "--shape", "serve_p99",
            "--mesh", "single", "--out", str(tmp_path),
        ],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"

    rec_path = tmp_path / "sasrec__serve_p99__single.json"
    assert rec_path.exists()
    rec = json.loads(rec_path.read_text())
    assert rec["n_devices"] == 256
    assert rec["mesh"] == "16x16"
    assert rec["cost"].get("flops", 0) > 0
    assert "peak_bytes_per_device" in rec["memory"]

    from repro.launch.roofline import analyze_record

    out = analyze_record(str(rec_path))
    assert out["dominant"] in ("compute", "memory", "collective")
    assert out["compute_s"] >= 0 and out["memory_s"] > 0
