"""Launch-layer units: HLO collective parser, mesh helpers, config registry."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.launch.dryrun import _shape_bytes, collective_stats


def test_registry_covers_10_archs():
    assert len(ARCH_IDS) == 10
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        assert arch.shape_names(), aid


def test_cell_enumeration_counts():
    cells = all_cells()
    by_family = {}
    for aid, shape in cells:
        fam = get_arch(aid).family
        by_family[fam] = by_family.get(fam, 0) + 1
    # 4 full-attention LMs x 3 + gemma3 x 4 = 16; 4 gnn; 16 recsys
    assert by_family == {"lm": 16, "gnn": 4, "recsys": 16}
    assert len(cells) == 36  # + 4 documented long_500k skips = 40 assigned


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,3]{1,0}") == 12
    assert _shape_bytes("(f32[4], s32[4])") == 32
    assert _shape_bytes("pred[]") == 1


def test_collective_stats_parses_and_multiplies():
    hlo = """
ENTRY %main () -> f32[8] {
  %x = f32[1024,256]{1,0} all-gather(f32[64,256]{1,0} %p), replica_groups={}
  %y = f32[512]{0} all-reduce(f32[512]{0} %q), to_apply=%add
  %z = f32[32,16]{1,0} reduce-scatter(f32[512,16]{1,0} %r), dimensions={0}
  %w = bf16[64]{0} all-to-all(bf16[64]{0} %s)
  %cp = f32[16]{0} collective-permute(f32[16]{0} %t)
  %ag2 = f32[128]{0} all-gather-start(f32[8]{0} %u)
}
"""
    st = collective_stats(hlo)
    assert st["ops"] == 6
    ar = st["by_kind"]["all-reduce"]
    assert ar["result_bytes"] == 512 * 4
    assert ar["wire_bytes"] == 512 * 4 * 2.0  # ring all-reduce 2x
    ag = st["by_kind"]["all-gather"]
    assert ag["ops"] == 2


def test_mesh_helpers():
    from repro.launch.mesh import batch_axes_of, data_parallelism, make_host_mesh

    m = make_host_mesh(1)
    assert batch_axes_of(m) == ()
    assert data_parallelism(m) == 1


def test_lm_arch_skips_long_for_full_attention():
    assert "long_500k" not in get_arch("internlm2-20b").shape_names()
    assert "long_500k" in get_arch("gemma3-12b").shape_names()


def test_param_counts_match_advertised_scale():
    """Model sizes land near the advertised parameter counts."""
    p20 = get_arch("internlm2-20b").cfg.param_count()
    assert 17e9 < p20 < 23e9, p20
    p235 = get_arch("qwen3-moe-235b-a22b").cfg.param_count()
    assert 210e9 < p235 < 260e9, p235
    a22 = get_arch("qwen3-moe-235b-a22b").cfg.active_param_count()
    assert 18e9 < a22 < 26e9, a22
    p314 = get_arch("grok-1-314b").cfg.param_count()
    assert 290e9 < p314 < 340e9, p314
    p12 = get_arch("gemma3-12b").cfg.param_count()
    assert 10e9 < p12 < 14e9, p12
    p2 = get_arch("granite-3-2b").cfg.param_count()
    assert 2e9 < p2 < 4e9, p2
