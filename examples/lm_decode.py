"""LM serving-substrate demo: greedy generation through the prefill +
ring-buffer-decode path on a reduced gemma3-style hybrid (5 sliding : 1
global attention), verifying decode-vs-full-forward consistency live.

  PYTHONPATH=src python examples/lm_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import (
    TransformerConfig,
    forward,
    init,
    serve_prefill,
    serve_step,
)


def main():
    cfg = TransformerConfig(
        name="gemma3-tiny",
        n_layers=6,
        d_model=128,
        n_heads=4,
        n_kv=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        window_pattern=(16, 16, 16, 16, 16, None),  # 5:1 local:global
        tied_embed=True,
        dtype=jnp.float32,
        attn_chunk=16,
        kv_chunk=16,
        remat=False,
    )
    params, _ = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S, STEPS = 2, 32, 16
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))

    print(f"prefill {S} tokens (cache: sliding layers keep {16} slots, "
          f"global layers {S + STEPS})...")
    logits, caches = serve_prefill(params, prompt, cfg, max_len=S + STEPS)
    toks = prompt
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    step = jax.jit(
        lambda p, c, t, off: serve_step(p, c, t, off, cfg),
    )
    max_err = 0.0
    for i in range(STEPS):
        lg, caches = step(params, caches, nxt, jnp.int32(S + i))
        toks = jnp.concatenate([toks, nxt], axis=1)
        # cross-check against the full forward every few steps
        if i % 4 == 0:
            lg_full, _ = forward(params, toks, cfg)
            rel = float(jnp.max(jnp.abs(lg_full[:, -1] - lg))) / float(
                jnp.max(jnp.abs(lg_full[:, -1]))
            )
            max_err = max(max_err, rel)
            assert bool(
                (jnp.argmax(lg_full[:, -1], -1) == jnp.argmax(lg, -1)).all()
            ), "decode diverged from forward"
        nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)

    print(f"generated {STEPS} tokens/seq; decode-vs-forward max relative "
          f"logit err = {max_err:.2e} (fp32 reduction-order noise; argmax "
          f"identical at every checked step)")
    print("sequences:", np.asarray(toks)[:, -8:].tolist())


if __name__ == "__main__":
    main()
