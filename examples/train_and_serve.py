"""End-to-end driver (the paper's own pipeline): train matrix-factorization
embeddings on synthetic user-item interactions (the paper's Yahoo!Music setup
— ALS-style MF; we use AdamW SGD), then serve top-10 MIPS recommendation
queries through the ip-NSW+ index and compare against brute force.

  PYTHONPATH=src python examples/train_and_serve.py [--steps 300]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import IpNSWPlus, exact_topk, recall_at_k
from repro.train import adamw_init, adamw_update, loop
from repro.data.synthetic import SyntheticLMStream  # noqa: F401 (pattern ref)


def make_interactions(n_users, n_items, d_true, rng):
    """Ground-truth low-rank preference matrix -> implicit-feedback samples."""
    u = rng.normal(size=(n_users, d_true)).astype(np.float32) / np.sqrt(d_true)
    v = rng.normal(size=(n_items, d_true)).astype(np.float32) / np.sqrt(d_true)
    return u, v


class InteractionStream:
    def __init__(self, u_true, v_true, batch, seed=0):
        self.u, self.v, self.batch, self.seed = u_true, v_true, batch, seed

    def batch_at(self, step):
        rng = np.random.default_rng((self.seed << 32) + step)
        ui = rng.integers(0, len(self.u), self.batch)
        ii = rng.integers(0, len(self.v), self.batch)
        r = np.einsum("bd,bd->b", self.u[ui], self.v[ii])
        return {
            "users": ui.astype(np.int32),
            "items": ii.astype(np.int32),
            "ratings": r.astype(np.float32),
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-users", type=int, default=2000)
    ap.add_argument("--n-items", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    u_true, v_true = make_interactions(args.n_users, args.n_items, args.dim, rng)
    stream = InteractionStream(u_true, v_true, batch=4096)

    key = jax.random.PRNGKey(0)
    ku, kv = jax.random.split(key)
    params = {
        "user": jax.random.normal(ku, (args.n_users, args.dim)) * 0.1,
        "item": jax.random.normal(kv, (args.n_items, args.dim)) * 0.1,
    }
    state = {"params": params, "opt": adamw_init(params)}

    def mf_loss(p, batch):
        pu = p["user"][batch["users"]]
        pi = p["item"][batch["items"]]
        pred = jnp.sum(pu * pi, axis=-1)
        return jnp.mean((pred - batch["ratings"]) ** 2)

    @jax.jit
    def step_fn(state, batch):
        batch = jax.tree.map(jnp.asarray, batch)
        l, g = jax.value_and_grad(mf_loss)(state["params"], batch)
        p, o = adamw_update(g, state["opt"], state["params"], lr=3e-3,
                            weight_decay=0.0)
        return {"params": p, "opt": o}, {"loss": l}

    print(f"== training MF ({args.n_users}x{args.n_items}, d={args.dim}) ==")
    res = loop.run(step_fn, state, stream, n_steps=args.steps,
                   ckpt_dir=args.ckpt_dir, log_every=100)
    print(f"loss {res.history[0]['loss']:.4f} -> {res.history[-1]['loss']:.4f}")

    item_emb = jnp.asarray(res.state["params"]["item"])
    user_emb = jnp.asarray(res.state["params"]["user"][:512])

    print("== building ip-NSW+ over trained item embeddings ==")
    t0 = time.time()
    index = IpNSWPlus(max_degree=16, ef_construction=32, insert_batch=512).build(item_emb)
    print(f"built in {time.time()-t0:.0f}s")

    _, gt = exact_topk(user_emb, item_emb, k=10)
    print("== serving 512 users, top-10 recommendation ==")
    for ef in (20, 40, 80):
        r = index.search(user_emb, k=10, ef=ef)
        rec = recall_at_k(np.asarray(r.ids), np.asarray(gt))
        ev = float(np.mean(np.asarray(r.evals)))
        print(f"ef={ef:3d}: recall@10={rec:.3f}  evals/query={ev:.0f} "
              f"({ev/args.n_items:.1%} of corpus)")


if __name__ == "__main__":
    main()
