"""Reproduce the paper's §2-§3 analyses on synthetic data: norm bias of the
MIPS ground truth (Fig 1), Theorem-1 curve (Fig 3a), cardinality effect
(Fig 3b), in-degree concentration (Fig 4), computation concentration (Fig 5).

  PYTHONPATH=src python examples/norm_bias_analysis.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import IpNSW, exact_topk
from repro.core.graph import in_degrees
from repro.core.norms import (
    norm_group_of,
    group_occupancy,
    theorem1_probability,
    top_group_share,
    tailing_factor,
)
from repro.data import mips_dataset, mips_queries


def main():
    n, d, b = 20_000, 64, 500
    items = mips_dataset(n, d, profile="lognormal", seed=0)
    queries = mips_queries(b, d, seed=1)
    norms = np.linalg.norm(items, axis=1)

    print(f"== dataset: N={n}, d={d}, tailing factor {tailing_factor(norms):.2f} ==\n")

    _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(items), k=10)
    gt = np.asarray(gt)
    print("Fig 1 — norm bias of exact top-10 MIPS:")
    print(f"  top-5%-norm items hold {top_group_share(gt, norms, 5.0):.1%} of the result set")
    print(f"  (paper: 87.5%-100% on its four real datasets)\n")

    print("Fig 3a — Theorem 1, P[qx >= qy] for norm ratio sqrt(alpha):")
    for a in (1.0, 1.35, 2.0, 4.0):
        print(f"  alpha={a:4.2f}: P = {theorem1_probability(a):.3f}")
    print("  (modest per-pair edge -> cardinality amplifies it, Fig 3b)\n")

    rng = np.random.default_rng(0)
    print("Fig 3b — cardinality effect (same norm profile, smaller N):")
    for rate in (0.02, 0.1, 1.0):
        m = int(n * rate)
        sub = items[rng.choice(n, m, replace=False)]
        _, g = exact_topk(jnp.asarray(queries), jnp.asarray(sub), k=10)
        share = top_group_share(np.asarray(g), np.linalg.norm(sub, axis=1), 5.0)
        print(f"  N={m:6d}: top-5% share {share:.1%}")
    print()

    print("building ip-NSW for Fig 4/5 ...")
    idx = IpNSW(max_degree=16, ef_construction=32, insert_batch=512).build(
        jnp.asarray(items)
    )
    ind = in_degrees(idx.graph)
    groups = norm_group_of(norms, 20)
    top5 = ind[groups == 0].mean()
    print("Fig 4 — in-degree concentration in the ip-NSW graph:")
    print(f"  top-5%-norm avg in-degree {top5:.1f} = {top5/ind.mean():.1f}x dataset avg "
          f"(paper: 3.2x-19.8x)\n")

    res = idx.search(jnp.asarray(queries), k=10, ef=64)
    occ = group_occupancy(np.asarray(res.visited), groups, 20)
    print("Fig 5 — where the walk spends its similarity evaluations:")
    print(f"  top-5%-norm items receive {occ[0]:.1%} of evaluations "
          f"(paper: 80.7%-100%)")


if __name__ == "__main__":
    main()
