import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed MIPS serving demo: items row-sharded into 8 shard-local
ip-NSW+ sub-indexes; queries fan out via shard_map, per-shard top-k merge
with one tiny all-gather; a dead shard degrades recall, not availability.

  PYTHONPATH=src python examples/distributed_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import exact_topk, recall_at_k
from repro.core.distributed import build_sharded, sharded_search
from repro.data import mips_dataset, mips_queries


def main():
    n, d, b, k, shards = 16_000, 48, 64, 10, 8
    items = jnp.asarray(mips_dataset(n, d, profile="lognormal", seed=0))
    queries = jnp.asarray(mips_queries(b, d, seed=1))
    _, gt = exact_topk(queries, items, k=k)
    gt = np.asarray(gt)

    print(f"building {shards} shard-local ip-NSW+ indexes ({n//shards} items "
          f"each; scan backend = all shards in one device program)...")
    index = build_sharded(items, shards, plus=True, build_backend="scan",
                          max_degree=16, ef_construction=32, insert_batch=512)

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((shards,), ("model",))
    print(f"mesh: {mesh}")

    ids, scores, evals = sharded_search(index, queries, mesh=mesh, k=k, ef=40)
    print(f"all shards up:   recall@10 = {recall_at_k(np.asarray(ids), gt):.3f}  "
          f"(total evals/query {float(np.mean(np.asarray(evals))):.0f})")

    # kill shard 3: serving continues, recall degrades gracefully
    mask = np.ones(shards, bool)
    mask[3] = False
    ids_dg, _, _ = sharded_search(index, queries, mesh=mesh, k=k, ef=40,
                                  shard_mask=jnp.asarray(mask))
    print(f"shard 3 down:    recall@10 = {recall_at_k(np.asarray(ids_dg), gt):.3f}  "
          f"(availability preserved; launcher rebuilds the shard from its item partition)")


if __name__ == "__main__":
    main()
