"""Quickstart: build ip-NSW and ip-NSW+ over a synthetic embedding corpus,
run batched MIPS queries, and compare recall / evaluation counts against the
exact linear scan.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import IpNSW, IpNSWPlus, exact_topk, recall_at_k
from repro.data import mips_dataset, mips_queries


def main():
    n, d, b, k = 20_000, 64, 256, 10
    items = jnp.asarray(mips_dataset(n, d, profile="lognormal", seed=0))
    queries = jnp.asarray(mips_queries(b, d, seed=1))

    print(f"dataset: {n} items x {d} dims; {b} queries; top-{k} MIPS")
    _, gt = exact_topk(queries, items, k=k)
    gt = np.asarray(gt)

    print("building ip-NSW (baseline)...")
    base = IpNSW(max_degree=16, ef_construction=32, insert_batch=512).build(items)
    print("building ip-NSW+ (the paper's contribution)...")
    plus = IpNSWPlus(max_degree=16, ef_construction=32, insert_batch=512).build(items)

    print(f"{'algo':8s} {'ef':>4s} {'recall@10':>10s} {'evals/query':>12s} {'vs brute':>9s}")
    for ef in (10, 20, 40, 80):
        r1 = base.search(queries, k=k, ef=ef)
        r2 = plus.search(queries, k=k, ef=ef)
        for name, r in (("ip-NSW", r1), ("ip-NSW+", r2)):
            rec = recall_at_k(np.asarray(r.ids), gt)
            ev = float(np.mean(np.asarray(r.evals)))
            print(f"{name:8s} {ef:4d} {rec:10.3f} {ev:12.0f} {ev/n:8.1%}")


if __name__ == "__main__":
    main()
