"""jax version-compat shims for the pinned jax (0.4.37).

The codebase targets the modern surface (``jax.shard_map`` with
``check_vma=``, mesh ``axis_types=``); on the pinned 0.4.x these live under
``jax.experimental.shard_map`` with ``check_rep=``, and
``jax.sharding.AxisType`` does not exist.  Centralizing the fallbacks here
keeps every call site on the one modern spelling; bumping the jax pin means
revisiting exactly this module.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types on meshes
    from jax.sharding import AxisType
except ImportError:  # pinned jax 0.4.x has neither AxisType nor the kwarg
    AxisType = None


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: passes Auto axis_types when the
    installed jax supports them, plain mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        # check_vma is the renamed check_rep (replication checking).
        return _shard_map_legacy(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )
