"""Dense-adjacency proximity graph — the TPU-native replacement for
pointer-chasing adjacency lists.

A graph over N items with max out-degree M is a single ``[N, M]`` int32 array
(-1 = empty slot).  Out-degree is bounded by construction (Algorithm 2 /
HNSW-style pruning); in-degree is unbounded, which is exactly the quantity the
paper's Figure 4 analyses.  All updates are functional (.at[].set), so the
build loop is jit-able per insertion batch and the structure is a pytree that
shards row-wise across the ``model`` mesh axis.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class GraphIndex(NamedTuple):
    """Proximity graph + the vectors it indexes.

    adj:    [N, M] int32 out-neighbor ids, -1 padded.
    items:  [N, d] vectors the similarity is computed against (possibly
            pre-transformed, e.g. normalized for the angular graph).
    size:   [] int32, number of inserted items (rows >= size are empty).
    entry:  [] int32, entry vertex id for graph walks.
    entry_norm: [] fp32, norm of the entry vertex (-inf while empty).
            Carried so ``commit_batch`` advances the max-norm entry with an
            O(B) compare against the batch instead of a full [N] masked
            argmax.  ``None`` on legacy instances — consumers fall back to
            gathering ``norms[entry]``.
    """

    adj: jax.Array
    items: jax.Array
    size: jax.Array
    entry: jax.Array
    entry_norm: Optional[jax.Array] = None

    @property
    def capacity(self) -> int:
        return self.adj.shape[0]

    @property
    def max_degree(self) -> int:
        return self.adj.shape[1]


def empty_graph(items: jax.Array, max_degree: int) -> GraphIndex:
    n = items.shape[0]
    adj = jnp.full((n, max_degree), -1, dtype=jnp.int32)
    return GraphIndex(
        adj=adj,
        items=items,
        size=jnp.zeros((), jnp.int32),
        entry=jnp.zeros((), jnp.int32),
        entry_norm=jnp.full((), -jnp.inf, jnp.float32),
    )


def in_degrees(graph: GraphIndex) -> np.ndarray:
    """In-degree of every vertex (host-side; analysis/Fig-4 utility)."""
    adj = np.asarray(graph.adj)
    size = int(graph.size)
    flat = adj[:size].reshape(-1)
    flat = flat[flat >= 0]
    return np.bincount(flat, minlength=graph.capacity)


def out_degrees(graph: GraphIndex) -> np.ndarray:
    adj = np.asarray(graph.adj)
    return (adj >= 0).sum(axis=1)
