"""Hierarchical NSW (the paper's footnote: "ip-NSW actually adopts multiple
hierarchical layers of NSW (known as HNSW)").

Level assignment: item level ~ floor(-ln(U) * mL), mL = 1/ln(M) (Malkov &
Yashunin).  Level k holds every item with level >= k as its own NSW graph
(built by core/build.py over the subset); level 0 holds all items.

Search descends: greedy walk (beam=1) from the top level's entry to level 1,
then a full beam search on level 0 seeded at the descent result.  Upper
levels are tiny (N/M^k items), so the descent costs O(levels * M) extra
evaluations but starts the level-0 walk near the query's neighborhood —
useful when the entry-point heuristic (max-norm item) is weak, e.g. flat
norm distributions.

TPU mapping: every level is a dense GraphIndex; per-level local ids map to
global ids via ``ids[level]`` arrays; the descent is the same batched beam
search with pool_size=1.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.build import build_graph
from repro.core.graph import GraphIndex
from repro.core.search import SearchResult, beam_search
from repro.core.similarity import Similarity
from repro.core.storage import ItemStore, make_store, validate_storage


def assign_levels(n: int, max_degree: int, seed: int = 0, max_levels: int = 6):
    rng = np.random.default_rng(seed)
    ml = 1.0 / np.log(max(max_degree, 2))
    lv = np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int32)
    return np.minimum(lv, max_levels - 1)


@functools.partial(
    jax.jit, static_argnames=("k", "ef", "max_steps", "backend", "storage")
)
def _level0_search(graph, queries, init, store=None, *, k, ef, max_steps,
                   backend="reference", storage="f32"):
    return beam_search(graph, queries, init, pool_size=max(ef, k),
                       max_steps=max_steps, k=k, backend=backend,
                       storage=storage, store=store)


@functools.partial(
    jax.jit, static_argnames=("max_steps", "backend", "storage")
)
def _greedy_descend(graph, queries, init, store=None, *, max_steps,
                    backend="reference", storage="f32"):
    r = beam_search(graph, queries, init, pool_size=1, max_steps=max_steps, k=1,
                    backend=backend, storage=storage, store=store)
    return r.ids[:, 0], r.evals


@dataclass
class HierarchicalIpNSW:
    """ip-NSW with HNSW-style layers (inner-product similarity on every
    level)."""

    max_degree: int = 16
    ef_construction: int = 64
    insert_batch: int = 256
    seed: int = 0
    backend: str = "reference"       # walk step backend (search.STEP_BACKENDS)
    build_backend: str = "host"      # insertion driver (build.BUILD_BACKENDS)
    commit_backend: str = "reference"  # reverse-link merge (COMMIT_BACKENDS)
    commit_tile: Union[int, str] = "auto"  # fused-commit grid tiling (§7)
    storage: str = "f32"             # item store search streams (DESIGN.md §8)
    levels: List[GraphIndex] = field(default_factory=list)
    ids: List[np.ndarray] = field(default_factory=list)       # level -> global ids
    inv: List[np.ndarray] = field(default_factory=list)       # global -> local (-1)
    stores: List[Optional[ItemStore]] = field(default_factory=list)

    def build(self, items: jax.Array, progress: bool = False):
        validate_storage(self.storage)
        items = jnp.asarray(items)
        n = items.shape[0]
        lv = assign_levels(n, self.max_degree, self.seed)
        n_levels = int(lv.max()) + 1
        self.levels, self.ids, self.inv = [], [], []
        for level in range(n_levels):
            sel = np.nonzero(lv >= level)[0].astype(np.int32)
            if len(sel) < 2:
                break
            sub = items[jnp.asarray(sel)]
            g = build_graph(
                sub,
                similarity=Similarity.INNER_PRODUCT,
                max_degree=self.max_degree if level == 0 else self.max_degree // 2 or 2,
                ef_construction=self.ef_construction if level == 0 else max(
                    self.ef_construction // 4, 8
                ),
                insert_batch=self.insert_batch,
                backend=self.backend,
                build_backend=self.build_backend,
                commit_backend=self.commit_backend,
                commit_tile=self.commit_tile,
                progress=progress and level == 0,
            )
            inv = np.full(n, -1, np.int32)
            inv[sel] = np.arange(len(sel), dtype=np.int32)
            self.levels.append(g)
            self.ids.append(sel)
            self.inv.append(inv)
        # One store per level (levels are distinct item subsets); the upper
        # levels are tiny (N/M^k rows), so the extra stores cost ~nothing.
        self.stores = [make_store(g.items, self.storage) for g in self.levels]
        return self

    def _resolve_stores(self, storage: str) -> List[Optional[ItemStore]]:
        validate_storage(storage)
        if storage == "f32":
            return [None] * len(self.levels)
        if not self.stores or self.stores[0] is None:
            self.stores = [make_store(g.items, storage) for g in self.levels]
        return self.stores

    def search(self, queries: jax.Array, k: int = 10, ef: int = 64,
               max_steps: Optional[int] = None,
               backend: Optional[str] = None,
               storage: Optional[str] = None) -> SearchResult:
        assert self.levels, "call build() first"
        backend = backend if backend is not None else self.backend
        storage = storage if storage is not None else self.storage
        stores = self._resolve_stores(storage)
        b = queries.shape[0]
        extra_evals = jnp.zeros((b,), jnp.int32)

        # descend from the top level down to level 1
        cur_global = None
        for level in range(len(self.levels) - 1, 0, -1):
            g = self.levels[level]
            if cur_global is None:
                init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
            else:
                local = jnp.asarray(self.inv[level])[cur_global]
                local = jnp.where(local >= 0, local, g.entry)
                init = local[:, None].astype(jnp.int32)
            best_local, ev = _greedy_descend(
                g, queries, init, stores[level],
                max_steps=4 * self.max_degree, backend=backend,
                storage=storage,
            )
            cur_global = jnp.asarray(self.ids[level])[jnp.maximum(best_local, 0)]
            extra_evals = extra_evals + ev

        g0 = self.levels[0]
        if cur_global is None:
            init0 = jnp.broadcast_to(g0.entry[None, None], (b, 1)).astype(jnp.int32)
        else:
            init0 = cur_global[:, None].astype(jnp.int32)  # level0 local == global
        steps = max_steps if max_steps is not None else 2 * ef
        res = _level0_search(g0, queries, init0, stores[0], k=k, ef=ef,
                             max_steps=steps, backend=backend, storage=storage)
        return SearchResult(
            ids=res.ids,
            scores=res.scores,
            evals=res.evals + extra_evals,
            steps=res.steps,
            visited=res.visited,
        )
