"""Sharded MIPS index — the multi-pod serving path.

Items are row-sharded into P shards; every shard builds its OWN proximity
graph(s) over its local items (graph edges never cross shards, so a shard is
a self-contained index that can be rebuilt/replaced independently — this is
the fault-tolerance unit).  A query fans out to the shards, walks the local
graph, and the per-shard top-k (k ids + scores, tiny) are merged with a
single all-gather + static top-k.

Two partition policies (``build_sharded(partition=)``):

  "roundrobin"  — the legacy uniform split: contiguous global-id blocks of
                  ceil(N/P) rows each.  Every shard sees the same norm
                  distribution, so every query must visit every shard.
  "norm_bands"  — the Norm-Range partition (Yan et al.'s follow-ups to the
                  source paper: arXiv 1809.08782 / 1810.09104): the catalog
                  is sorted by ||x|| and cut into P contiguous, count-
                  balanced bands.  Band 0 holds the largest norms.  Each
                  shard records its ``max_norm``, giving every query q the
                  per-shard score upper bound ``max_norm_s * ||q||`` —
                  the Cauchy-Schwarz certificate the routing layer below
                  skips shards with.

Routing (``route=`` on both search drivers): visit shards in descending
``max_norm`` order; before walking shard s for query q, compare the bound
``max_norm_s * ||q||`` against q's current global k-th best score.  If the
bound is strictly below, NO item in shard s can enter q's top-k (every
score is <= ||x||*||q|| <= the bound), so the walk is skipped — provably
zero recall loss, and on heavy-tailed (lognormal) catalogs most low-norm
bands are skipped for most queries.  ``sharded_search_reference`` defines
the exact semantics with a sequential scan over shards (the k-th score
tightens after every visited shard); ``sharded_search`` implements it
inside the shard_map body as a two-phase masked walk (top band first, then
every other shard masked per query by the top band's k-th score) so all
shapes stay static and the steady state never recompiles.  Skipped
(shard, query) pairs ride ``beam_search(valid=)``: born done, zero evals.

Communication cost per query batch B: all-gathers of [B, k] fp32 + [B, k]
int32 over the ``model`` axis — k*P*8 bytes per query, independent of N
(twice that with routing, for the two merge rounds).  That is the
collective term in the roofline model (launch/roofline.py).

Elastic / degraded serving: ``shard_mask`` disables dead shards at merge
time (their scores become -inf) so a lost host degrades recall instead of
availability; the launcher rebuilds the missing shard from the checkpointed
item partition and re-enables it.

Storage tiering (``storage="tiered"``): the hot top band — where the norm
bias concentrates the answers — serves f32 walks while every colder band
walks its int8 quantized store (exact fp32 rerank per shard as usual), so
the catalog's HBM footprint shrinks ~4x everywhere the paper says the
answers aren't.

Streaming churn on the sharded path: ``ShardedMutable`` keeps one
``core.mutation.MutableIndex`` per band, routes upserts to the band whose
norm range covers the new item (falling back to the nearest band with free
slots, widening that band's recorded ``max_norm`` so the routing bound
stays a true upper bound), maps tombstone deletes global-id -> (shard,
slot), and snapshots back into a ``ShardedIndex`` whose per-shard ``live``
masks thread through the banded merge.
"""
from __future__ import annotations

import functools
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.graph import GraphIndex
from repro.core.search import beam_search
from repro.core.storage import ItemStore, quantize_items, validate_storage

NEG_INF = jnp.float32(-jnp.inf)

PARTITION_BACKENDS = ("roundrobin", "norm_bands")
ROUTE_MODES = ("none", "upper_bound")
# The sharded path accepts one storage value beyond STORAGE_BACKENDS:
# "tiered" = f32 on the hottest (max ``max_norm``) shard, int8 elsewhere.
SHARD_STORAGE = ("f32", "int8", "tiered")


def validate_partition(partition: str) -> None:
    if partition not in PARTITION_BACKENDS:
        raise ValueError(
            f"partition must be one of {PARTITION_BACKENDS}, "
            f"got {partition!r}"
        )


def validate_route(route: str) -> None:
    if route not in ROUTE_MODES:
        raise ValueError(f"route must be one of {ROUTE_MODES}, got {route!r}")


def _validate_shard_storage(storage: str) -> None:
    if storage not in SHARD_STORAGE:
        raise ValueError(
            f"sharded storage must be one of {SHARD_STORAGE}, got {storage!r}"
        )


class ShardedIndex(NamedTuple):
    """Stacked per-shard graphs (leading axis = shard).

    ip: GraphIndex with adj [P, Nloc, M], items [P, Nloc, d], size/entry [P]
    ang: same for the angular graph, or None for plain ip-NSW
    offset: [P] global-id offset of every shard (roundrobin partitions only
           — banded partitions carry the explicit ``gid`` map instead)
    count: [P] number of REAL items per shard, or None (legacy indexes).
           The tail shard is zero-padded to Nloc at build time; pad nodes are
           real graph vertices locally, so the merge must drop local ids
           >= count — otherwise their 0.0 scores outrank genuine
           negative-score items and surface global ids >= N.
    store / ang_store: stacked per-shard int8 item stores (codes
           [P, Nloc, d], scales [P, Nloc]) for ``storage="int8"`` serving,
           or None (f32 / legacy indexes).  Tail-shard pad rows quantize to
           all-zero codes, so their quantized scores are exactly the fp32
           path's 0.0 and the same ``count`` mask drops them at merge.
    live:  [P, Nloc] bool per-shard tombstone masks (core/mutation.py), or
           None (no deletions).  ``count`` only masks the zero-pad TAIL of
           the last shard; an INTERIOR delete is a live catalog row gone
           stale, which only this mask can drop — both inside the local
           walks (dead nodes route but never surface, search.beam_search)
           and again at the merge, so a shard whose local top-k still cites
           a tombstone cannot leak it into the global result.
    gid:   [P, Nloc] int32 global catalog id of every local row, or None
           (roundrobin: global id = local id + offset).  Banded partitions
           permute the catalog, so the merge gathers this map instead of
           adding an offset; pad rows carry -1 (the count/live masks drop
           them before the gather matters).
    max_norm: [P] fp32 max ||x|| over each shard's REAL rows, or None
           (legacy).  The routing layer's whole correctness argument rests
           on this being a true upper bound — pinned by the partition
           property in tests/test_properties.py.  It is recorded at build
           time and only ever widened (ShardedMutable), never tightened,
           so tombstoning a shard's largest item cannot invalidate it.
    """

    ip: GraphIndex
    ang: Optional[GraphIndex]
    offset: jax.Array
    count: Optional[jax.Array] = None
    store: Optional[ItemStore] = None
    ang_store: Optional[ItemStore] = None
    live: Optional[jax.Array] = None
    gid: Optional[jax.Array] = None
    max_norm: Optional[jax.Array] = None


class RouteStats(NamedTuple):
    """Per-query routing telemetry (``return_stats=True`` on the drivers).

    shards_visited: [B] int32 — shards whose local walk actually ran for
                    this query (masked-out walks are born done: 0 evals).
    bound_skips:    [B] int32 — live shards skipped because
                    ``max_norm_s * ||q|| < kth_score`` (dead shards under
                    ``shard_mask`` count in neither column).
    """

    shards_visited: jax.Array
    bound_skips: jax.Array


def norm_band_partition(
    norms, n_shards: int
) -> Tuple[List[np.ndarray], np.ndarray]:
    """Cut the catalog into ``n_shards`` contiguous norm bands, balanced by
    item count (host-side; build-time only).

    Returns ``(bands, band_max)``: ``bands[s]`` is the int32 global-id array
    of band s — band 0 holds the LARGEST norms — and ``band_max[s]`` its max
    norm (0.0 for an empty band).  Sorting is stable with ties broken by id,
    so the partition is deterministic; the union of the bands is exactly a
    permutation of ``arange(N)`` and ``band_max`` bounds every member —
    the two invariants the routing skip rule rests on, pinned by the
    hypothesis property in tests/test_properties.py.
    """
    norms = np.asarray(norms, np.float64)
    n = norms.shape[0]
    if n_shards <= 0:
        raise ValueError(f"n_shards must be positive, got {n_shards}")
    per = -(-n // n_shards)
    order = np.argsort(-norms, kind="stable")
    bands = [
        np.asarray(order[s * per : (s + 1) * per], np.int32)
        for s in range(n_shards)
    ]
    band_max64 = np.asarray(
        [float(norms[b].max()) if len(b) else 0.0 for b in bands],
        np.float64,
    )
    # the fp32 cast must round UP: a band_max half an ulp below the true max
    # would let the skip rule discard a shard that holds the best answer
    band_max = band_max64.astype(np.float32)
    low = band_max.astype(np.float64) < band_max64
    band_max[low] = np.nextafter(band_max[low], np.float32(np.inf))
    return bands, band_max


def stack_shards(
    ip_graphs: Sequence[GraphIndex],
    ang_graphs: Optional[Sequence[GraphIndex]] = None,
    counts: Optional[Sequence[int]] = None,
    gids: Optional[Sequence[np.ndarray]] = None,
    max_norms: Optional[Sequence[float]] = None,
) -> ShardedIndex:
    stack = lambda *xs: jnp.stack(xs)
    ip = jax.tree.map(stack, *ip_graphs)
    ang = jax.tree.map(stack, *ang_graphs) if ang_graphs is not None else None
    sizes = [int(g.items.shape[0]) for g in ip_graphs]
    offsets = jnp.asarray(
        [sum(sizes[:i]) for i in range(len(sizes))], jnp.int32
    )
    count = jnp.asarray(list(counts), jnp.int32) if counts is not None else None
    gid = None
    if gids is not None:
        nloc = sizes[0]
        padded = []
        for rows in gids:
            g = np.full(nloc, -1, np.int32)
            g[: len(rows)] = rows
            padded.append(g)
        gid = jnp.asarray(np.stack(padded))
    mn = (
        jnp.asarray(np.asarray(max_norms, np.float32))
        if max_norms is not None else None
    )
    return ShardedIndex(
        ip=ip, ang=ang, offset=offsets, count=count, gid=gid, max_norm=mn
    )


def build_sharded(
    items: jax.Array,
    n_shards: int,
    *,
    plus: bool = True,
    build_backend: str = "host",
    storage: str = "f32",
    partition: str = "roundrobin",
    **index_kwargs,
) -> ShardedIndex:
    """Split ``items`` into ``n_shards`` row shards and build one local
    index per shard.

    ``partition="roundrobin"`` keeps the legacy contiguous uniform split;
    ``"norm_bands"`` sorts the catalog by ||x|| and cuts count-balanced
    bands (band 0 = largest norms), recording the per-shard ``gid`` map and
    ``max_norm`` bound that ``route="upper_bound"`` skips shards with.
    ``max_norm`` is recorded for BOTH partitions, so routing runs (if
    pointlessly) on roundrobin too.

    ``build_backend="host"`` builds shards sequentially (each a host-loop or
    scan build per ``index_kwargs``); ``"scan"`` vmaps the fully-traced scan
    build over the shard axis, so all P shard graphs build inside ONE device
    program.  ``index_kwargs`` are IpNSW / IpNSWPlus constructor fields
    (including ``backend=`` for the insertion walks, ``commit_backend=`` for
    the reverse-link merge kernel, and ``commit_tile=`` for its grid tiling
    — the scan path resolves ``"auto"`` once, on host, from the pooled
    shard norms, so every vmapped shard runs the same static tile).
    ``storage="int8"`` derives stacked per-shard quantized stores post-build
    (builds stay fp32, DESIGN.md §8); ``"tiered"`` derives the same stores
    but serves the hottest band in f32 (pass the matching ``storage=`` to
    the search drivers).
    """
    from repro.core.ipnsw import IpNSW
    from repro.core.ipnsw_plus import IpNSWPlus

    _validate_shard_storage(storage)
    validate_partition(partition)
    n = items.shape[0]
    per = -(-n // n_shards)
    norms_np = np.linalg.norm(np.asarray(items, np.float32), axis=-1)
    if partition == "norm_bands":
        bands, band_max = norm_band_partition(norms_np, n_shards)
    else:
        bands = [
            np.arange(s * per, min((s + 1) * per, n), dtype=np.int32)
            for s in range(n_shards)
        ]
        band_max = np.asarray(
            [float(norms_np[b].max()) if len(b) else 0.0 for b in bands],
            np.float32,
        )
    counts = [len(b) for b in bands]
    gids = bands if partition == "norm_bands" else None

    locals_ = []
    for rows in bands:
        local = jnp.asarray(np.asarray(items)[rows])
        if local.shape[0] < per:  # pad the ragged tail shard with zeros
            pad = per - local.shape[0]
            local = jnp.concatenate(
                [local, jnp.zeros((pad, items.shape[-1]), items.dtype)]
            )
        locals_.append(local)

    if build_backend == "scan":
        index = _build_sharded_scan(locals_, counts, plus=plus, **index_kwargs)
        index = index._replace(
            gid=_pad_gids(gids, per) if gids is not None else None,
            max_norm=jnp.asarray(band_max),
        )
        return _attach_stores(index, storage)

    ip_graphs, ang_graphs = [], []
    for local in locals_:
        if plus:
            idx = IpNSWPlus(**index_kwargs).build(local)
            ip_graphs.append(idx.ip_graph)
            ang_graphs.append(idx.ang_graph)
        else:
            idx = IpNSW(**index_kwargs).build(local)
            ip_graphs.append(idx.graph)
    index = stack_shards(
        ip_graphs, ang_graphs if plus else None, counts,
        gids=gids, max_norms=band_max,
    )
    return _attach_stores(index, storage)


def _pad_gids(gids: Sequence[np.ndarray], nloc: int) -> jax.Array:
    padded = []
    for rows in gids:
        g = np.full(nloc, -1, np.int32)
        g[: len(rows)] = rows
        padded.append(g)
    return jnp.asarray(np.stack(padded))


def _attach_stores(index: ShardedIndex, storage: str) -> ShardedIndex:
    """Derive stacked per-shard quantized stores from the frozen shard items
    (quantize_items maps over the leading shard axis unchanged — scales
    reduce over the feature axis only).  ``tiered`` needs the same stores:
    every shard but the hottest walks them."""
    if storage not in ("int8", "tiered"):
        return index
    return index._replace(
        store=quantize_items(index.ip.items),
        ang_store=(
            quantize_items(index.ang.items) if index.ang is not None else None
        ),
    )


def _build_sharded_scan(
    locals_: Sequence[jax.Array],
    counts: Sequence[int],
    *,
    plus: bool,
    **index_kwargs,
) -> ShardedIndex:
    """Shard-parallel scan build: one jit, vmap over the shard axis."""
    from repro.core.build import (
        batch_schedule, resolve_commit_tile, scan_build_arrays,
    )
    from repro.core.ipnsw import IpNSW
    from repro.core.ipnsw_plus import IpNSWPlus, scan_build_plus_arrays
    from repro.core.similarity import normalize

    proto = (IpNSWPlus if plus else IpNSW)(**index_kwargs)

    p = len(locals_)
    per = int(locals_[0].shape[0])
    stacked = jnp.stack(locals_)                      # [P, Nloc, d]
    norms = jnp.linalg.norm(stacked, axis=-1)         # [P, Nloc]
    # Static tile for every shard's commits, resolved before the vmap trace
    # (inside it the norms are abstract and "auto" could not use the skew).
    commit_tile = resolve_commit_tile(
        proto.commit_tile,
        e=proto.insert_batch * proto.max_degree,
        norms=norms,
    )
    _, bids, valid = batch_schedule(per, proto.insert_batch)
    bids, valid = jnp.asarray(bids), jnp.asarray(valid)
    offsets = jnp.asarray([s * per for s in range(p)], jnp.int32)
    count = jnp.asarray(list(counts), jnp.int32)

    if plus:
        ang_items = normalize(stacked)
        ang_norms = jnp.ones((p, per), jnp.float32)
        fn = functools.partial(
            scan_build_plus_arrays,
            max_degree=proto.max_degree,
            ef_construction=proto.ef_construction,
            ang_degree=proto.ang_degree,
            ang_ef=proto.ang_ef,
            k_angular=proto.k_angular,
            insert_batch=proto.insert_batch,
            reverse_links=proto.reverse_links,
            backend=proto.backend,
            commit_backend=proto.commit_backend,
            commit_tile=commit_tile,
        )
        (a_adj, a_size, a_entry, a_enorm,
         i_adj, i_size, i_entry, i_enorm) = jax.jit(
            jax.vmap(lambda it, ai, no, an: fn(it, ai, no, an, bids, valid))
        )(stacked, ang_items, norms, ang_norms)
        ip = GraphIndex(adj=i_adj, items=stacked, size=i_size, entry=i_entry,
                        entry_norm=i_enorm)
        ang = GraphIndex(adj=a_adj, items=ang_items, size=a_size,
                         entry=a_entry, entry_norm=a_enorm)
        return ShardedIndex(ip=ip, ang=ang, offset=offsets, count=count)

    fn = functools.partial(
        scan_build_arrays,
        max_degree=proto.max_degree,
        ef=proto.ef_construction,
        max_steps=2 * proto.ef_construction,
        insert_batch=proto.insert_batch,
        reverse_links=proto.reverse_links,
        backend=proto.backend,
        commit_backend=proto.commit_backend,
        commit_tile=commit_tile,
    )
    adj, size, entry, enorm = jax.jit(
        jax.vmap(lambda it, no: fn(it, no, bids, valid))
    )(stacked, norms)
    ip = GraphIndex(adj=adj, items=stacked, size=size, entry=entry,
                    entry_norm=enorm)
    return ShardedIndex(ip=ip, ang=None, offset=offsets, count=count)


# ---------------------------------------------------------------------------
# Local search bodies (operate on a single shard's graphs)
# ---------------------------------------------------------------------------


def _local_ipnsw(
    graphs: ShardedIndex,
    queries: jax.Array,
    *,
    k: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
    storage: str = "f32",
    valid: Optional[jax.Array] = None,
):
    g = graphs.ip
    b = queries.shape[0]
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    res = beam_search(
        g, queries, init, pool_size=max(ef, k), max_steps=max_steps, k=k,
        backend=backend, storage=storage,
        store=graphs.store if storage == "int8" else None,
        live=graphs.live, valid=valid,
    )
    return res.ids, res.scores, res.evals


def _local_ipnsw_plus(
    graphs: ShardedIndex,
    queries: jax.Array,
    *,
    k: int,
    ef: int,
    max_steps: int,
    ang_ef: int = 10,
    k_angular: int = 10,
    backend: str = "reference",
    storage: str = "f32",
    valid: Optional[jax.Array] = None,
):
    from repro.core.ipnsw_plus import _seed_from_angular

    b = queries.shape[0]
    ang = graphs.ang
    init_a = jnp.broadcast_to(ang.entry[None, None], (b, 1)).astype(jnp.int32)
    a = beam_search(
        ang,
        queries,
        init_a,
        pool_size=max(ang_ef, k_angular),
        max_steps=2 * max(ang_ef, k_angular),
        k=k_angular,
        backend=backend,
        storage=storage,
        store=graphs.ang_store if storage == "int8" else None,
        live=graphs.live,
        valid=valid,
    )
    seeds = _seed_from_angular(graphs.ip.adj, a.ids)
    r = beam_search(
        graphs.ip, queries, seeds, pool_size=max(ef, k), max_steps=max_steps, k=k,
        backend=backend, storage=storage,
        store=graphs.store if storage == "int8" else None,
        live=graphs.live, valid=valid,
    )
    return r.ids, r.scores, a.evals + r.evals


def _globalize(blk: ShardedIndex, ids: jax.Array, scores: jax.Array):
    """Map local result ids to global ids, dropping pad and tombstoned nodes.

    Pad rows of the tail shard are genuine local graph vertices with
    zero vectors (score 0.0); without the ``count`` mask they would
    outrank real negative-score items and surface ids >= N.  ``count``
    is a tail bound only — an INTERIOR tombstone (streaming delete,
    core/mutation.py) needs the ``live`` row mask; the local walks already
    filter it, and masking here again makes the merge safe even against a
    local path that missed the mask (defense in depth for the latent gap
    pinned in tests/test_mutation.py).  Banded shards hold a permuted slice
    of the catalog, so their global ids come from the ``gid`` gather, not
    the offset."""
    keep = ids >= 0
    if blk.count is not None:
        keep &= ids < blk.count
    if blk.live is not None:
        keep &= blk.live.astype(bool)[jnp.maximum(ids, 0)]
    if blk.gid is not None:
        gids = blk.gid[jnp.maximum(ids, 0)]
        keep &= gids >= 0
        gids = jnp.where(keep, gids, -1)
    else:
        gids = jnp.where(keep, ids + blk.offset, -1)
    return gids, jnp.where(keep, scores, NEG_INF)


# ---------------------------------------------------------------------------
# Merge + drivers
# ---------------------------------------------------------------------------


def _merge_topk(all_ids, all_scores, k: int, shard_mask=None):
    """[P, B, k] -> replicated global top-k [B, k] (ids already global)."""
    p = all_ids.shape[0]
    if shard_mask is not None:
        all_scores = jnp.where(shard_mask[:, None, None], all_scores, NEG_INF)
    ids = jnp.moveaxis(all_ids, 0, 1).reshape(all_ids.shape[1], p * k)
    scores = jnp.moveaxis(all_scores, 0, 1).reshape(all_ids.shape[1], p * k)
    vals, sel = jax.lax.top_k(scores, k)
    out_ids = jnp.take_along_axis(ids, sel, axis=-1)
    return jnp.where(vals > NEG_INF, out_ids, -1), vals


def _merge_pair(run_ids, run_scores, new_ids, new_scores, k: int):
    """Fold one shard's [B, k] candidates into the running global top-k.
    Ties prefer the running entries (top_k picks the lower index), so a
    skipped shard — whose rows arrive as (-1, -inf) — never perturbs the
    carry."""
    ids = jnp.concatenate([run_ids, new_ids], axis=-1)
    scores = jnp.concatenate([run_scores, new_scores], axis=-1)
    vals, sel = jax.lax.top_k(scores, k)
    out = jnp.take_along_axis(ids, sel, axis=-1)
    return jnp.where(vals > NEG_INF, out, -1), vals


def shard_visit_mask(max_norm_s, qnorm, kth_score):
    """The routing decision, stated once: visit shard s for query q iff its
    Cauchy-Schwarz bound could still beat q's current k-th best score.
    A shard is skipped IFF ``max_norm_s * ||q|| < kth_score`` — strict, so
    a bound exactly equal to the k-th score still visits (an item could tie
    it).  Pinned as a unit rule in tests/test_shard_routing.py; every
    routed driver goes through here."""
    return max_norm_s * qnorm >= kth_score


def _make_local_fn(
    plus: bool, ang_ef: int, k_angular: int, storage: str = "f32"
) -> Callable:
    if plus:
        return functools.partial(
            _local_ipnsw_plus, ang_ef=ang_ef, k_angular=k_angular,
            storage=storage,
        )
    return functools.partial(_local_ipnsw, storage=storage)


def _tier_storage(storage: str, is_hot) -> str:
    """Resolve the per-shard storage under tiering: the hottest shard walks
    f32, every colder one its int8 store."""
    if storage != "tiered":
        return storage
    return "f32" if is_hot else "int8"


def _require_route_index(index: ShardedIndex, route: str, storage: str):
    if (route != "none" or storage == "tiered") and index.max_norm is None:
        raise ValueError(
            "routing/tiering need per-shard max_norm bounds — rebuild with "
            "build_sharded(...) (any partition records them) or attach "
            "index._replace(max_norm=...)"
        )


def sharded_search(
    index: ShardedIndex,
    queries: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "model",
    k: int = 10,
    ef: int = 64,
    max_steps: Optional[int] = None,
    plus: bool = True,
    shard_mask: Optional[jax.Array] = None,
    backend: str = "reference",
    ang_ef: int = 10,
    k_angular: int = 10,
    storage: str = "f32",
    route: str = "none",
    return_stats: bool = False,
):
    """shard_map driver: local walk on every shard + all-gather top-k merge.

    Queries are replicated over ``axis`` (shard the batch over the remaining
    mesh axes with in_shardings at the jit level).  ``backend`` selects the
    walk step kernel for the local searches ("reference" | "pallas", see
    search.STEP_BACKENDS); ``ang_ef``/``k_angular`` parameterize the angular
    stage of the ip-NSW+ local walks (pass the values the index was built
    with — they are search-time knobs, not baked into the index).
    ``storage="int8"`` walks each shard's quantized store (built via
    ``build_sharded(storage="int8")``) with the per-shard exact fp32 rerank
    before the merge — the merged scores stay exact inner products, and the
    ``count`` mask drops tail-shard pad nodes exactly as on the f32 path.
    An f32-built index searched with int8 gets its stores derived here at
    the driver level, once per call — build with ``storage="int8"`` to skip
    that re-derivation entirely.

    ``route="upper_bound"`` turns on shard routing as a two-phase masked
    walk inside the shard_map body: phase 1 walks only the hottest shard
    (max ``max_norm``) and all-gathers its global top-k; phase 2 walks
    every other shard with the per-query mask
    ``shard_visit_mask(max_norm_s, ||q||, kth_phase1)``, so a (shard,
    query) pair whose bound cannot beat the top band's k-th score spends
    ZERO walk evals (``beam_search(valid=)`` rows are born done).  Both
    phases are fixed-shape — routing changes mask values, never shapes, so
    the compiled program is reused across calls (zero steady recompiles).
    The skip rule only drops provably-uncontributing shards, so results
    match the exhaustive ``route="none"`` merge (up to cross-shard score
    ties); the sequential reference oracle
    (``sharded_search_reference(route="upper_bound")``) skips at least as
    much because its k-th score tightens after every visited shard.
    ``storage="tiered"`` rides the same two phases: phase 1 is the f32 hot
    walk, phase 2 the int8 cold walk.  ``return_stats=True`` appends a
    ``RouteStats`` (per-query shards visited / bound skips).
    """
    _validate_shard_storage(storage)
    validate_route(route)
    _require_route_index(index, route, storage)
    if storage == "tiered" and route == "none":
        raise ValueError(
            "storage='tiered' on the shard_map path requires "
            "route='upper_bound' (the hot/cold walk phases ARE the routing "
            "phases); use sharded_search_reference for unrouted tiering"
        )
    if storage in ("int8", "tiered") and index.store is None:
        index = _attach_stores(index, storage)
    steps = max_steps if max_steps is not None else 2 * ef
    mask = shard_mask if shard_mask is not None else jnp.ones(
        (index.offset.shape[0],), bool
    )

    if route == "none":
        local_fn = _make_local_fn(plus, ang_ef, k_angular, storage)

        def body(idx_blk: ShardedIndex, mask_blk, q):
            blk = jax.tree.map(lambda x: x[0], idx_blk)  # strip unit shard dim
            ids, scores, evals = local_fn(
                blk, q, k=k, ef=ef, max_steps=steps, backend=backend
            )
            gids, scores = _globalize(blk, ids, scores)
            all_ids = jax.lax.all_gather(gids, axis)        # [P, B, k]
            all_scores = jax.lax.all_gather(scores, axis)
            all_mask = jax.lax.all_gather(mask_blk[0], axis)
            out_ids, out_scores = _merge_topk(all_ids, all_scores, k, all_mask)
            total_evals = jax.lax.psum(evals, axis)
            b = q.shape[0]
            visited = jnp.broadcast_to(
                all_mask.sum().astype(jnp.int32), (b,))
            skips = jnp.zeros((b,), jnp.int32)
            return out_ids, out_scores, total_evals, visited, skips

        spec_idx = jax.tree.map(lambda _: P(axis), index)
        out = shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_idx, P(axis), P()),
            out_specs=(P(), P(), P(), P(), P()),
            check_vma=False,
        )(index, mask, queries)
        if return_stats:
            return out[0], out[1], out[2], RouteStats(out[3], out[4])
        return out[:3]

    # route == "upper_bound": two-phase masked walk.
    p = index.offset.shape[0]
    order = jnp.argsort(-index.max_norm)
    ranks = jnp.zeros((p,), jnp.int32).at[order].set(
        jnp.arange(p, dtype=jnp.int32))
    hot_fn = _make_local_fn(plus, ang_ef, k_angular,
                            _tier_storage(storage, True))
    cold_fn = _make_local_fn(plus, ang_ef, k_angular,
                             _tier_storage(storage, False))

    def body(idx_blk: ShardedIndex, mask_blk, rank_blk, q):
        blk = jax.tree.map(lambda x: x[0], idx_blk)
        mask_s, rank = mask_blk[0], rank_blk[0]
        b = q.shape[0]
        qnorm = jnp.linalg.norm(q, axis=-1)
        hot = (rank == 0) & mask_s
        v1 = jnp.broadcast_to(hot, (b,))
        ids1, sc1, ev1 = hot_fn(
            blk, q, k=k, ef=ef, max_steps=steps, backend=backend, valid=v1)
        g1, s1 = _globalize(blk, ids1, sc1)
        all1_ids = jax.lax.all_gather(g1, axis)
        all1_sc = jax.lax.all_gather(s1, axis)
        all_mask = jax.lax.all_gather(mask_s, axis)
        _, m_sc = _merge_topk(all1_ids, all1_sc, k, all_mask)
        kth = m_sc[:, k - 1]                      # [B] top band's k-th score
        v2 = (~hot) & mask_s & shard_visit_mask(blk.max_norm, qnorm, kth)
        ids2, sc2, ev2 = cold_fn(
            blk, q, k=k, ef=ef, max_steps=steps, backend=backend, valid=v2)
        g2, s2 = _globalize(blk, ids2, sc2)
        all2_ids = jax.lax.all_gather(g2, axis)
        all2_sc = jax.lax.all_gather(s2, axis)
        out_ids, out_scores = _merge_topk(
            jnp.concatenate([all1_ids, all2_ids], axis=0),
            jnp.concatenate([all1_sc, all2_sc], axis=0),
            k,
            jnp.concatenate([all_mask, all_mask], axis=0),
        )
        total_evals = jax.lax.psum(ev1 + ev2, axis)
        visited = jax.lax.psum(
            v1.astype(jnp.int32) + v2.astype(jnp.int32), axis)
        skips = jax.lax.psum(
            ((~hot) & mask_s & ~v2).astype(jnp.int32)
            * jnp.ones((b,), jnp.int32), axis)
        return out_ids, out_scores, total_evals, visited, skips

    spec_idx = jax.tree.map(lambda _: P(axis), index)
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_idx, P(axis), P(axis), P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False,
    )(index, mask, ranks, queries)
    if return_stats:
        return out[0], out[1], out[2], RouteStats(out[3], out[4])
    return out[:3]


def sharded_search_reference(
    index: ShardedIndex,
    queries: jax.Array,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: Optional[int] = None,
    plus: bool = True,
    shard_mask: Optional[jax.Array] = None,
    backend: str = "reference",
    ang_ef: int = 10,
    k_angular: int = 10,
    storage: str = "f32",
    route: str = "none",
    return_stats: bool = False,
):
    """Single-device oracle: identical math to ``sharded_search`` with the
    shard dimension mapped by vmap instead of shard_map.  Used by tests to
    pin down the distributed semantics on CPU.

    With ``route="upper_bound"`` this path DEFINES the routing semantics:
    an unrolled sequential pass over the shards in descending ``max_norm``
    order, carrying the running global top-k.  Before each shard, query q's
    walk is masked out iff ``shard_visit_mask`` says the shard's bound is
    strictly below q's current k-th score — every skipped shard is provably
    unable to contribute, so routed results equal the exhaustive merge (up
    to cross-shard score ties).  The sequential k-th score is tighter than
    the device path's phase-1 score, so this oracle skips at least as many
    shards.  ``storage="tiered"`` serves the first (hottest) shard f32 and
    the rest int8 on the same unrolled pass."""
    _validate_shard_storage(storage)
    validate_route(route)
    _require_route_index(index, route, storage)
    if storage in ("int8", "tiered") and index.store is None:
        index = _attach_stores(index, storage)
    steps = max_steps if max_steps is not None else 2 * ef
    p = index.offset.shape[0]
    b = queries.shape[0]

    if route == "none" and storage != "tiered":
        local_fn = _make_local_fn(plus, ang_ef, k_angular, storage)

        def one(blk: ShardedIndex):
            ids, scores, evals = local_fn(
                blk, queries, k=k, ef=ef, max_steps=steps, backend=backend
            )
            gids, scores = _globalize(blk, ids, scores)
            return gids, scores, evals

        all_ids, all_scores, all_evals = jax.vmap(one)(index)
        out_ids, out_scores = _merge_topk(all_ids, all_scores, k, shard_mask)
        if return_stats:
            mask = shard_mask if shard_mask is not None else jnp.ones(
                (p,), bool)
            visited = jnp.broadcast_to(
                mask.sum().astype(jnp.int32), (b,))
            stats = RouteStats(visited, jnp.zeros((b,), jnp.int32))
            return out_ids, out_scores, all_evals.sum(axis=0), stats
        return out_ids, out_scores, all_evals.sum(axis=0)

    # Sequential pass (routing and/or tiering), shards in descending
    # max_norm order.  Unrolled in Python: each iteration may bind a
    # different static storage knob, and P is small.
    use_bound = route == "upper_bound"
    mask = shard_mask if shard_mask is not None else jnp.ones((p,), bool)
    qnorm = jnp.linalg.norm(queries, axis=-1)
    order = jnp.argsort(-index.max_norm)
    run_ids = jnp.full((b, k), -1, jnp.int32)
    run_scores = jnp.full((b, k), NEG_INF, jnp.float32)
    evals = jnp.zeros((b,), jnp.int32)
    visited = jnp.zeros((b,), jnp.int32)
    skips = jnp.zeros((b,), jnp.int32)
    for i in range(p):
        s = order[i]
        blk = jax.tree.map(lambda x: x[s], index)
        local_fn = _make_local_fn(
            plus, ang_ef, k_angular, _tier_storage(storage, i == 0))
        live_shard = jnp.broadcast_to(mask[s], (b,))
        if use_bound:
            kth = run_scores[:, k - 1]
            visit = live_shard & shard_visit_mask(blk.max_norm, qnorm, kth)
        else:
            visit = live_shard
        ids, scores, ev = local_fn(
            blk, queries, k=k, ef=ef, max_steps=steps, backend=backend,
            valid=visit,
        )
        gids, scores = _globalize(blk, ids, scores)
        run_ids, run_scores = _merge_pair(run_ids, run_scores, gids, scores, k)
        evals = evals + ev
        visited = visited + visit.astype(jnp.int32)
        skips = skips + (live_shard & ~visit).astype(jnp.int32)
    if return_stats:
        return run_ids, run_scores, evals, RouteStats(visited, skips)
    return run_ids, run_scores, evals


# ---------------------------------------------------------------------------
# Streaming churn on the banded path
# ---------------------------------------------------------------------------


class ShardedMutable:
    """Norm-banded sharded index opened for streaming mutation: one
    ``core.mutation.MutableIndex`` per band, plus the global-id bookkeeping
    the banded merge needs.

    * Upserts route each new item to the band whose norm range covers it
      (band edges = the build-time per-band min norms).  A full band falls
      back to the nearest band with free slots; whichever band receives the
      item has its recorded ``max_norm`` widened to cover it, so the
      routing bound stays a TRUE upper bound under churn — tombstoning
      never tightens it (a stale-high bound only costs a wasted visit,
      never recall).
    * Deletes map global ids to (band, slot) tombstones; slots are reused
      FIFO per band by the underlying ``MutableIndex`` pools.
    * ``snapshot()`` restacks the padded per-band graphs into a
      ``ShardedIndex`` whose ``live``/``gid``/``max_norm``/``count`` fields
      make the routed, banded merge churn-safe — serve it with either
      search driver.

    Every band is padded to the same ``capacity = ceil(N/P) + headroom``
    rows so the snapshot stacks rectangularly; per-band invariants I1–I6
    remain checkable via ``check_invariants()``.
    """

    def __init__(
        self,
        items,
        n_shards: int,
        *,
        plus: bool = False,
        headroom: int = 64,
        mutation_batch: int = 16,
        relink_threshold: float = 0.3,
        **index_kwargs,
    ):
        from repro.core.ipnsw import IpNSW
        from repro.core.ipnsw_plus import IpNSWPlus
        from repro.core.mutation import MutableIndex

        items = np.asarray(items, np.float32)
        n = items.shape[0]
        if n < n_shards:
            raise ValueError(
                f"need at least one item per band: n={n} < P={n_shards}"
            )
        norms = np.linalg.norm(items, axis=-1)
        bands, band_max = norm_band_partition(norms, n_shards)
        self.n_shards = n_shards
        self.plus = plus
        self.capacity = -(-n // n_shards) + int(headroom)
        self.max_norm = np.asarray(band_max, np.float32).copy()
        # Band lower edges (min member norm) — the routing table upserts
        # consult.  Descending like the bands themselves.
        self.band_lo = np.asarray(
            [float(norms[bnd].min()) if len(bnd) else 0.0 for bnd in bands],
            np.float32,
        )
        self.shards: List = []
        self._gids: List[np.ndarray] = []
        self._slot_of: dict = {}      # global id -> (band, slot)
        self._next_gid = n
        cls = IpNSWPlus if plus else IpNSW
        for bnd in bands:
            idx = cls(**index_kwargs).build(jnp.asarray(items[bnd]))
            self.shards.append(MutableIndex(
                idx, capacity=self.capacity, mutation_batch=mutation_batch,
                relink_threshold=relink_threshold,
            ))
            gid = np.full(self.capacity, -1, np.int32)
            gid[: len(bnd)] = bnd
            self._gids.append(gid)
            for slot, g in enumerate(bnd):
                self._slot_of[int(g)] = (len(self.shards) - 1, slot)

    # -- routing -----------------------------------------------------------

    def _route_band(self, norm: float, need: int = 1) -> int:
        """Preferred band = hottest band whose lower edge covers ``norm``;
        fall back outward to the nearest band with ``need`` free slots."""
        fits = np.flatnonzero(self.band_lo <= norm)
        pref = int(fits[0]) if len(fits) else self.n_shards - 1
        for s in sorted(range(self.n_shards),
                        key=lambda s: (abs(s - pref), s)):
            if self.shards[s].free_slots() >= need:
                return s
        raise RuntimeError(
            "every band's free-slot pool is exhausted — grow headroom= or "
            "delete first"
        )

    # -- mutations ---------------------------------------------------------

    def upsert(self, new_items) -> np.ndarray:
        """Insert a batch; returns the new GLOBAL ids, in payload order."""
        new_items = np.asarray(new_items, np.float32)
        norms = np.linalg.norm(new_items, axis=-1)
        by_band: dict = {}
        gids = np.empty(len(new_items), np.int32)
        for i, v in enumerate(norms):
            s = self._route_band(float(v))
            # Account for rows already queued on this band this batch.
            while self.shards[s].free_slots() <= len(by_band.get(s, [])):
                nxt = [t for t in range(self.n_shards)
                       if self.shards[t].free_slots() > len(by_band.get(t, []))]
                if not nxt:
                    raise RuntimeError(
                        "every band's free-slot pool is exhausted — grow "
                        "headroom= or delete first"
                    )
                s = min(nxt, key=lambda t: (abs(t - s), t))
            gids[i] = self._next_gid
            self._next_gid += 1
            by_band.setdefault(s, []).append(i)
        for s, rows in by_band.items():
            slots = self.shards[s].upsert(new_items[rows])
            self.max_norm[s] = max(
                float(self.max_norm[s]), float(norms[rows].max())
            )
            for i, slot in zip(rows, slots):
                slot = int(slot)
                self._gids[s][slot] = gids[i]
                self._slot_of[int(gids[i])] = (s, slot)
        return gids

    def delete(self, global_ids) -> None:
        """Tombstone a batch of live global ids (any mix of bands)."""
        by_band: dict = {}
        for g in np.unique(np.asarray(global_ids, np.int64).ravel()):
            loc = self._slot_of.get(int(g))
            if loc is None:
                raise ValueError(f"global id {int(g)} is not live")
            by_band.setdefault(loc[0], []).append(loc[1])
        for s, slots in by_band.items():
            self.shards[s].delete(slots)
            for slot in slots:
                g = int(self._gids[s][slot])
                self._gids[s][slot] = -1
                self._slot_of.pop(g, None)

    def kill_hubs(self, band: int, k: int) -> np.ndarray:
        """Adversarial fault injection on one band: tombstone its k highest
        in-degree live nodes (at most all-but-one).  Returns the GLOBAL ids
        killed — on the top band these are the §4 routing hubs whose loss
        stresses both navigability and the banded merge."""
        local = self.shards[band].kill_hubs(k)
        gids = self._gids[band][local].copy()
        for slot in local:
            g = int(self._gids[band][slot])
            self._gids[band][slot] = -1
            self._slot_of.pop(g, None)
        return gids

    # -- repair / health ---------------------------------------------------

    def _orphan_slots(self, band: int) -> np.ndarray:
        """Live slots of one band that no live node points to (and that are
        not a graph entry).  Tombstoning can sever every inbound edge of a
        survivor, and out-edge repair (``MutableIndex.relink``) can never
        make such a node findable again — it needs a re-seat, not an edge
        fix.  For plus indexes a slot only counts as orphaned when BOTH the
        ip and angular graphs have lost every live in-edge to it."""
        m = self.shards[band]
        live = m._live_host
        graphs = ([m.index.ip_graph, m.index.ang_graph] if self.plus
                  else [m.index.graph])
        orphan = live.copy()
        for g in graphs:
            adj = np.asarray(g.adj)[: m.size]
            edge = (adj >= 0) & live[: m.size, None]
            indeg = np.zeros(len(live), np.int64)
            np.add.at(indeg, adj[edge], 1)
            reachable = indeg > 0
            reachable[int(g.entry)] = True
            orphan &= ~reachable
        return np.flatnonzero(orphan).astype(np.int32)

    def _reseat(self, band: int, slot: int) -> None:
        """Re-insert an orphaned slot's item under its existing global id:
        a fresh insertion re-runs the reverse-link commit, which is what
        normally restores inbound edges.  Deleting an orphan rots nobody's
        edge list (no live node points at it, by definition).  A node whose
        score is too low to crack ANY neighbor's top-M edge list comes back
        from re-insertion still orphaned — those get one forced in-edge, so
        repair converges instead of re-seating the same node forever."""
        m = self.shards[band]
        gid = int(self._gids[band][slot])
        item = np.asarray(m.graph.items[slot]).copy()
        m.delete([slot])
        self._gids[band][slot] = -1
        new_slot = int(m.upsert(item[None, :])[0])
        self._gids[band][new_slot] = gid
        self._slot_of[gid] = (band, new_slot)
        if new_slot in self._orphan_slots(band):
            self._force_in_edge(band, new_slot)

    def _force_in_edge(self, band: int, slot: int) -> None:
        """Point one live node's edge at ``slot``.  Donors are tried
        best-IP-first; within a donor the evicted edge is the most
        redundant one (a -1 hole, else a dead target, else a live target
        with in-degree >= 2) so the eviction cannot orphan a third node.
        Keeps I1–I6: the new edge targets a live used slot and u != slot."""
        m = self.shards[band]
        idx = m.index
        g = idx.ip_graph if self.plus else idx.graph
        adj = np.asarray(g.adj)
        live = m._live_host
        size = m.size
        items = np.asarray(g.items)
        donors = np.flatnonzero(live[:size])
        donors = donors[donors != slot]
        if donors.size == 0:
            return
        donors = donors[np.argsort(-(items[donors] @ items[slot]))]
        indeg = np.zeros(len(live), np.int64)
        used = adj[:size]
        src_live = (used >= 0) & live[:size, None]
        np.add.at(indeg, used[src_live], 1)
        for u in donors:
            row = adj[u]
            holes = np.flatnonzero(row < 0)
            if holes.size:
                j = int(holes[0])
            else:
                dead = np.flatnonzero(~live[row])
                if dead.size:
                    j = int(dead[0])
                else:
                    red = np.flatnonzero(indeg[row] >= 2)
                    if red.size == 0:
                        continue
                    j = int(red[np.argmin(items[row[red]] @ items[u])])
            new_adj = g.adj.at[int(u), j].set(slot)
            ng = GraphIndex(new_adj, g.items, g.size, g.entry, g.entry_norm)
            if self.plus:
                idx.ip_graph = ng
            else:
                idx.graph = ng
            return

    def relink(self, budget: int) -> int:
        """Per-band repair, two stages under one budget: rewrite the
        rotted out-edge lists (``MutableIndex.relink``), then re-seat live
        nodes churn has orphaned entirely.  Both count toward
        ``relink_debt()``; loop until it reaches zero for a full repair."""
        done = 0
        for s, m in enumerate(self.shards):
            done += m.relink(budget)
            for slot in self._orphan_slots(s)[: max(int(budget), 0)]:
                self._reseat(s, int(slot))
                done += 1
        return done

    def relink_debt(self) -> int:
        return sum(m.relink_debt() for m in self.shards) + sum(
            len(self._orphan_slots(s)) for s in range(self.n_shards)
        )

    def check_invariants(self, max_dead_edge_frac: float = 1.0) -> List[str]:
        errs: List[str] = []
        for s, m in enumerate(self.shards):
            errs += [f"band{s}: {e}"
                     for e in m.check_invariants(max_dead_edge_frac)]
        return errs

    def live_gids(self) -> np.ndarray:
        return np.asarray(sorted(self._slot_of), np.int64)

    def live_items(self) -> Tuple[np.ndarray, np.ndarray]:
        """(gids, items) of the current live catalog, gid-sorted — the
        input a fresh banded rebuild would index."""
        gids = self.live_gids()
        rows = np.empty((len(gids), self.shards[0].graph.items.shape[1]),
                        np.float32)
        for i, g in enumerate(gids):
            s, slot = self._slot_of[int(g)]
            rows[i] = np.asarray(self.shards[s].graph.items[slot])
        return gids, rows

    # -- snapshot ----------------------------------------------------------

    def snapshot(self, storage: str = "f32") -> ShardedIndex:
        """Freeze the current state into a ``ShardedIndex`` for the banded
        merge drivers: stacked padded graphs, per-band live masks, the gid
        map, count = per-band slot high-water, and the (possibly widened)
        max_norm bounds."""
        stack = lambda *xs: jnp.stack(xs)
        if self.plus:
            ip = jax.tree.map(stack, *[m.index.ip_graph for m in self.shards])
            ang = jax.tree.map(stack,
                               *[m.index.ang_graph for m in self.shards])
        else:
            ip = jax.tree.map(stack, *[m.index.graph for m in self.shards])
            ang = None
        index = ShardedIndex(
            ip=ip,
            ang=ang,
            offset=jnp.asarray(
                [s * self.capacity for s in range(self.n_shards)], jnp.int32),
            count=jnp.asarray([m.size for m in self.shards], jnp.int32),
            live=jnp.stack([m.live for m in self.shards]),
            gid=jnp.asarray(np.stack(self._gids)),
            max_norm=jnp.asarray(self.max_norm),
        )
        return _attach_stores(index, storage)
