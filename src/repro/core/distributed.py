"""Sharded MIPS index — the multi-pod serving path.

Items are row-sharded into P contiguous shards; every shard builds its OWN
proximity graph(s) over its local items (graph edges never cross shards, so a
shard is a self-contained index that can be rebuilt/replaced independently —
this is the fault-tolerance unit).  A query fans out to all shards, walks the
local graph, and the per-shard top-k (k ids + scores, tiny) are merged with a
single all-gather + static top-k.

Communication cost per query batch B: one all-gather of [B, k] fp32 + [B, k]
int32 over the ``model`` axis — k*P*8 bytes per query, independent of N.
That is the collective term in the roofline model (launch/roofline.py).

Elastic / degraded serving: ``shard_mask`` disables dead shards at merge time
(their scores become -inf) so a lost host degrades recall instead of
availability; the launcher rebuilds the missing shard from the checkpointed
item partition and re-enables it.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.graph import GraphIndex
from repro.core.search import beam_search
from repro.core.storage import ItemStore, quantize_items, validate_storage

NEG_INF = jnp.float32(-jnp.inf)


class ShardedIndex(NamedTuple):
    """Stacked per-shard graphs (leading axis = shard).

    ip: GraphIndex with adj [P, Nloc, M], items [P, Nloc, d], size/entry [P]
    ang: same for the angular graph, or None for plain ip-NSW
    offset: [P] global-id offset of every shard
    count: [P] number of REAL items per shard, or None (legacy indexes).
           The tail shard is zero-padded to Nloc at build time; pad nodes are
           real graph vertices locally, so the merge must drop local ids
           >= count — otherwise their 0.0 scores outrank genuine
           negative-score items and surface global ids >= N.
    store / ang_store: stacked per-shard int8 item stores (codes
           [P, Nloc, d], scales [P, Nloc]) for ``storage="int8"`` serving,
           or None (f32 / legacy indexes).  Tail-shard pad rows quantize to
           all-zero codes, so their quantized scores are exactly the fp32
           path's 0.0 and the same ``count`` mask drops them at merge.
    live:  [P, Nloc] bool per-shard tombstone masks (core/mutation.py), or
           None (no deletions).  ``count`` only masks the zero-pad TAIL of
           the last shard; an INTERIOR delete is a live catalog row gone
           stale, which only this mask can drop — both inside the local
           walks (dead nodes route but never surface, search.beam_search)
           and again at the merge, so a shard whose local top-k still cites
           a tombstone cannot leak it into the global result.
    """

    ip: GraphIndex
    ang: Optional[GraphIndex]
    offset: jax.Array
    count: Optional[jax.Array] = None
    store: Optional[ItemStore] = None
    ang_store: Optional[ItemStore] = None
    live: Optional[jax.Array] = None


def stack_shards(
    ip_graphs: Sequence[GraphIndex],
    ang_graphs: Optional[Sequence[GraphIndex]] = None,
    counts: Optional[Sequence[int]] = None,
) -> ShardedIndex:
    stack = lambda *xs: jnp.stack(xs)
    ip = jax.tree.map(stack, *ip_graphs)
    ang = jax.tree.map(stack, *ang_graphs) if ang_graphs is not None else None
    sizes = [int(g.items.shape[0]) for g in ip_graphs]
    offsets = jnp.asarray(
        [sum(sizes[:i]) for i in range(len(sizes))], jnp.int32
    )
    count = jnp.asarray(list(counts), jnp.int32) if counts is not None else None
    return ShardedIndex(ip=ip, ang=ang, offset=offsets, count=count)


def build_sharded(
    items: jax.Array,
    n_shards: int,
    *,
    plus: bool = True,
    build_backend: str = "host",
    storage: str = "f32",
    **index_kwargs,
) -> ShardedIndex:
    """Split ``items`` into ``n_shards`` contiguous row shards and build one
    local index per shard.

    ``build_backend="host"`` builds shards sequentially (each a host-loop or
    scan build per ``index_kwargs``); ``"scan"`` vmaps the fully-traced scan
    build over the shard axis, so all P shard graphs build inside ONE device
    program.  ``index_kwargs`` are IpNSW / IpNSWPlus constructor fields
    (including ``backend=`` for the insertion walks, ``commit_backend=`` for
    the reverse-link merge kernel, and ``commit_tile=`` for its grid tiling
    — the scan path resolves ``"auto"`` once, on host, from the pooled
    shard norms, so every vmapped shard runs the same static tile).  ``storage="int8"`` derives stacked
    per-shard quantized stores post-build (builds stay fp32, DESIGN.md §8);
    pass the matching ``storage=`` to ``sharded_search`` to serve from them.
    """
    from repro.core.ipnsw import IpNSW
    from repro.core.ipnsw_plus import IpNSWPlus

    validate_storage(storage)
    n = items.shape[0]
    per = -(-n // n_shards)
    counts = [max(min(per, n - s * per), 0) for s in range(n_shards)]

    locals_ = []
    for s in range(n_shards):
        local = items[s * per : min((s + 1) * per, n)]
        if local.shape[0] < per:  # pad the ragged tail shard with zeros
            pad = per - local.shape[0]
            local = jnp.concatenate(
                [local, jnp.zeros((pad, items.shape[-1]), items.dtype)]
            )
        locals_.append(local)

    if build_backend == "scan":
        index = _build_sharded_scan(locals_, counts, plus=plus, **index_kwargs)
        return _attach_stores(index, storage)

    ip_graphs, ang_graphs = [], []
    for local in locals_:
        if plus:
            idx = IpNSWPlus(**index_kwargs).build(local)
            ip_graphs.append(idx.ip_graph)
            ang_graphs.append(idx.ang_graph)
        else:
            idx = IpNSW(**index_kwargs).build(local)
            ip_graphs.append(idx.graph)
    index = stack_shards(ip_graphs, ang_graphs if plus else None, counts)
    return _attach_stores(index, storage)


def _attach_stores(index: ShardedIndex, storage: str) -> ShardedIndex:
    """Derive stacked per-shard quantized stores from the frozen shard items
    (quantize_items maps over the leading shard axis unchanged — scales
    reduce over the feature axis only)."""
    if storage != "int8":
        return index
    return index._replace(
        store=quantize_items(index.ip.items),
        ang_store=(
            quantize_items(index.ang.items) if index.ang is not None else None
        ),
    )


def _build_sharded_scan(
    locals_: Sequence[jax.Array],
    counts: Sequence[int],
    *,
    plus: bool,
    **index_kwargs,
) -> ShardedIndex:
    """Shard-parallel scan build: one jit, vmap over the shard axis."""
    from repro.core.build import (
        batch_schedule, resolve_commit_tile, scan_build_arrays,
    )
    from repro.core.ipnsw import IpNSW
    from repro.core.ipnsw_plus import IpNSWPlus, scan_build_plus_arrays
    from repro.core.similarity import normalize

    proto = (IpNSWPlus if plus else IpNSW)(**index_kwargs)

    p = len(locals_)
    per = int(locals_[0].shape[0])
    stacked = jnp.stack(locals_)                      # [P, Nloc, d]
    norms = jnp.linalg.norm(stacked, axis=-1)         # [P, Nloc]
    # Static tile for every shard's commits, resolved before the vmap trace
    # (inside it the norms are abstract and "auto" could not use the skew).
    commit_tile = resolve_commit_tile(
        proto.commit_tile,
        e=proto.insert_batch * proto.max_degree,
        norms=norms,
    )
    _, bids, valid = batch_schedule(per, proto.insert_batch)
    bids, valid = jnp.asarray(bids), jnp.asarray(valid)
    offsets = jnp.asarray([s * per for s in range(p)], jnp.int32)
    count = jnp.asarray(list(counts), jnp.int32)

    if plus:
        ang_items = normalize(stacked)
        ang_norms = jnp.ones((p, per), jnp.float32)
        fn = functools.partial(
            scan_build_plus_arrays,
            max_degree=proto.max_degree,
            ef_construction=proto.ef_construction,
            ang_degree=proto.ang_degree,
            ang_ef=proto.ang_ef,
            k_angular=proto.k_angular,
            insert_batch=proto.insert_batch,
            reverse_links=proto.reverse_links,
            backend=proto.backend,
            commit_backend=proto.commit_backend,
            commit_tile=commit_tile,
        )
        (a_adj, a_size, a_entry, a_enorm,
         i_adj, i_size, i_entry, i_enorm) = jax.jit(
            jax.vmap(lambda it, ai, no, an: fn(it, ai, no, an, bids, valid))
        )(stacked, ang_items, norms, ang_norms)
        ip = GraphIndex(adj=i_adj, items=stacked, size=i_size, entry=i_entry,
                        entry_norm=i_enorm)
        ang = GraphIndex(adj=a_adj, items=ang_items, size=a_size,
                         entry=a_entry, entry_norm=a_enorm)
        return ShardedIndex(ip=ip, ang=ang, offset=offsets, count=count)

    fn = functools.partial(
        scan_build_arrays,
        max_degree=proto.max_degree,
        ef=proto.ef_construction,
        max_steps=2 * proto.ef_construction,
        insert_batch=proto.insert_batch,
        reverse_links=proto.reverse_links,
        backend=proto.backend,
        commit_backend=proto.commit_backend,
        commit_tile=commit_tile,
    )
    adj, size, entry, enorm = jax.jit(
        jax.vmap(lambda it, no: fn(it, no, bids, valid))
    )(stacked, norms)
    ip = GraphIndex(adj=adj, items=stacked, size=size, entry=entry,
                    entry_norm=enorm)
    return ShardedIndex(ip=ip, ang=None, offset=offsets, count=count)


# ---------------------------------------------------------------------------
# Local search bodies (operate on a single shard's graphs)
# ---------------------------------------------------------------------------


def _local_ipnsw(
    graphs: ShardedIndex,
    queries: jax.Array,
    *,
    k: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
    storage: str = "f32",
):
    g = graphs.ip
    b = queries.shape[0]
    init = jnp.broadcast_to(g.entry[None, None], (b, 1)).astype(jnp.int32)
    res = beam_search(
        g, queries, init, pool_size=max(ef, k), max_steps=max_steps, k=k,
        backend=backend, storage=storage,
        store=graphs.store if storage == "int8" else None,
        live=graphs.live,
    )
    return res.ids, res.scores, res.evals


def _local_ipnsw_plus(
    graphs: ShardedIndex,
    queries: jax.Array,
    *,
    k: int,
    ef: int,
    max_steps: int,
    ang_ef: int = 10,
    k_angular: int = 10,
    backend: str = "reference",
    storage: str = "f32",
):
    from repro.core.ipnsw_plus import _seed_from_angular

    b = queries.shape[0]
    ang = graphs.ang
    init_a = jnp.broadcast_to(ang.entry[None, None], (b, 1)).astype(jnp.int32)
    a = beam_search(
        ang,
        queries,
        init_a,
        pool_size=max(ang_ef, k_angular),
        max_steps=2 * max(ang_ef, k_angular),
        k=k_angular,
        backend=backend,
        storage=storage,
        store=graphs.ang_store if storage == "int8" else None,
        live=graphs.live,
    )
    seeds = _seed_from_angular(graphs.ip.adj, a.ids)
    r = beam_search(
        graphs.ip, queries, seeds, pool_size=max(ef, k), max_steps=max_steps, k=k,
        backend=backend, storage=storage,
        store=graphs.store if storage == "int8" else None,
        live=graphs.live,
    )
    return r.ids, r.scores, a.evals + r.evals


def _globalize(blk: ShardedIndex, ids: jax.Array, scores: jax.Array):
    """Map local result ids to global ids, dropping pad and tombstoned nodes.

    Pad rows of the tail shard are genuine local graph vertices with
    zero vectors (score 0.0); without the ``count`` mask they would
    outrank real negative-score items and surface ids >= N.  ``count``
    is a tail bound only — an INTERIOR tombstone (streaming delete,
    core/mutation.py) needs the ``live`` row mask; the local walks already
    filter it, and masking here again makes the merge safe even against a
    local path that missed the mask (defense in depth for the latent gap
    pinned in tests/test_mutation.py)."""
    keep = ids >= 0
    if blk.count is not None:
        keep &= ids < blk.count
    if blk.live is not None:
        keep &= blk.live.astype(bool)[jnp.maximum(ids, 0)]
    gids = jnp.where(keep, ids + blk.offset, -1)
    return gids, jnp.where(keep, scores, NEG_INF)


# ---------------------------------------------------------------------------
# Merge + drivers
# ---------------------------------------------------------------------------


def _merge_topk(all_ids, all_scores, k: int, shard_mask=None):
    """[P, B, k] -> replicated global top-k [B, k] (ids already global)."""
    p = all_ids.shape[0]
    if shard_mask is not None:
        all_scores = jnp.where(shard_mask[:, None, None], all_scores, NEG_INF)
    ids = jnp.moveaxis(all_ids, 0, 1).reshape(all_ids.shape[1], p * k)
    scores = jnp.moveaxis(all_scores, 0, 1).reshape(all_ids.shape[1], p * k)
    vals, sel = jax.lax.top_k(scores, k)
    out_ids = jnp.take_along_axis(ids, sel, axis=-1)
    return jnp.where(vals > NEG_INF, out_ids, -1), vals


def _make_local_fn(
    plus: bool, ang_ef: int, k_angular: int, storage: str = "f32"
) -> Callable:
    if plus:
        return functools.partial(
            _local_ipnsw_plus, ang_ef=ang_ef, k_angular=k_angular,
            storage=storage,
        )
    return functools.partial(_local_ipnsw, storage=storage)


def sharded_search(
    index: ShardedIndex,
    queries: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "model",
    k: int = 10,
    ef: int = 64,
    max_steps: Optional[int] = None,
    plus: bool = True,
    shard_mask: Optional[jax.Array] = None,
    backend: str = "reference",
    ang_ef: int = 10,
    k_angular: int = 10,
    storage: str = "f32",
):
    """shard_map driver: local walk on every shard + all-gather top-k merge.

    Queries are replicated over ``axis`` (shard the batch over the remaining
    mesh axes with in_shardings at the jit level).  ``backend`` selects the
    walk step kernel for the local searches ("reference" | "pallas", see
    search.STEP_BACKENDS); ``ang_ef``/``k_angular`` parameterize the angular
    stage of the ip-NSW+ local walks (pass the values the index was built
    with — they are search-time knobs, not baked into the index).
    ``storage="int8"`` walks each shard's quantized store (built via
    ``build_sharded(storage="int8")``) with the per-shard exact fp32 rerank
    before the merge — the merged scores stay exact inner products, and the
    ``count`` mask drops tail-shard pad nodes exactly as on the f32 path.
    An f32-built index searched with int8 gets its stores derived here at
    the driver level, once per call — build with ``storage="int8"`` to skip
    that re-derivation entirely.
    """
    validate_storage(storage)
    if storage == "int8" and index.store is None:
        index = _attach_stores(index, storage)
    steps = max_steps if max_steps is not None else 2 * ef
    local_fn = _make_local_fn(plus, ang_ef, k_angular, storage)
    mask = shard_mask if shard_mask is not None else jnp.ones(
        (index.offset.shape[0],), bool
    )

    def body(idx_blk: ShardedIndex, mask_blk, q):
        blk = jax.tree.map(lambda x: x[0], idx_blk)  # strip unit shard dim
        ids, scores, evals = local_fn(
            blk, q, k=k, ef=ef, max_steps=steps, backend=backend
        )
        gids, scores = _globalize(blk, ids, scores)
        all_ids = jax.lax.all_gather(gids, axis)        # [P, B, k]
        all_scores = jax.lax.all_gather(scores, axis)
        all_mask = jax.lax.all_gather(mask_blk[0], axis)
        out_ids, out_scores = _merge_topk(all_ids, all_scores, k, all_mask)
        total_evals = jax.lax.psum(evals, axis)
        return out_ids, out_scores, total_evals

    spec_idx = jax.tree.map(lambda _: P(axis), index)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_idx, P(axis), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )(index, mask, queries)


def sharded_search_reference(
    index: ShardedIndex,
    queries: jax.Array,
    *,
    k: int = 10,
    ef: int = 64,
    max_steps: Optional[int] = None,
    plus: bool = True,
    shard_mask: Optional[jax.Array] = None,
    backend: str = "reference",
    ang_ef: int = 10,
    k_angular: int = 10,
    storage: str = "f32",
):
    """Single-device oracle: identical math to ``sharded_search`` with the
    shard dimension mapped by vmap instead of shard_map.  Used by tests to
    pin down the distributed semantics on CPU."""
    validate_storage(storage)
    if storage == "int8" and index.store is None:
        index = _attach_stores(index, storage)
    steps = max_steps if max_steps is not None else 2 * ef
    local_fn = _make_local_fn(plus, ang_ef, k_angular, storage)

    def one(blk: ShardedIndex):
        ids, scores, evals = local_fn(
            blk, queries, k=k, ef=ef, max_steps=steps, backend=backend
        )
        gids, scores = _globalize(blk, ids, scores)
        return gids, scores, evals

    all_ids, all_scores, all_evals = jax.vmap(one)(index)
    out_ids, out_scores = _merge_topk(all_ids, all_scores, k, shard_mask)
    return out_ids, out_scores, all_evals.sum(axis=0)
