"""The paper's contribution: proximity-graph MIPS (ip-NSW / ip-NSW+) as a
composable, TPU-native JAX index library."""
from repro.core.brute_force import exact_topk
from repro.core.build import BUILD_BACKENDS, build_graph
from repro.core.graph import GraphIndex, empty_graph, in_degrees, out_degrees
from repro.core.hnsw import HierarchicalIpNSW
from repro.core.invariants import (
    assert_graph_invariants,
    check_graph_invariants,
    dead_edge_fraction,
)
from repro.core.ipnsw import IpNSW
from repro.core.ipnsw_plus import IpNSWPlus, PlusResult
from repro.core.lsh import SimpleLSH
# recall helpers live in the observability layer now (repro.obs.recall);
# re-exported here so `from repro.core import recall_at_k` keeps working
# without tripping the repro.core.metrics deprecation shim.
from repro.obs.recall import recall_at_k, recall_curve
from repro.core.mutation import (
    ChurnEvent,
    ChurnTrace,
    MutableIndex,
    apply_churn_event,
)
from repro.core.norm_filter import NormFilteredIndex
from repro.core.search import SearchResult, beam_search
from repro.core.similarity import Similarity, normalize
from repro.core.storage import (
    STORAGE_BACKENDS,
    ItemStore,
    dequantize,
    make_store,
    quantize_items,
)

__all__ = [
    "BUILD_BACKENDS",
    "STORAGE_BACKENDS",
    "ItemStore",
    "ChurnEvent",
    "ChurnTrace",
    "GraphIndex",
    "MutableIndex",
    "apply_churn_event",
    "assert_graph_invariants",
    "check_graph_invariants",
    "dead_edge_fraction",
    "HierarchicalIpNSW",
    "NormFilteredIndex",
    "IpNSW",
    "IpNSWPlus",
    "PlusResult",
    "SearchResult",
    "Similarity",
    "SimpleLSH",
    "beam_search",
    "build_graph",
    "dequantize",
    "empty_graph",
    "exact_topk",
    "in_degrees",
    "make_store",
    "normalize",
    "quantize_items",
    "out_degrees",
    "recall_at_k",
    "recall_curve",
]
