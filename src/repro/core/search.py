"""Batched graph-walk search (paper Algorithm 1), TPU-native.

The CPU reference implementation walks one query at a time with a priority
queue and a hash-set visited list.  Here B queries advance in lock-step inside
a single ``lax.while_loop``; every per-step operation is a dense gather,
matmul or top-k, so the walk lowers to MXU/VPU work and shards with pjit.

Per-query state:
  pool    — fixed-size candidate pool (ids, scores, checked), kept sorted by
            score descending (paper's candidate pool C with size l).
  visited — append-only ring buffer of every id that has been scored.  Dedup
            is a vectorized id-equality mask against this buffer; because each
            step appends exactly M slots for every query, the write offset is
            a *scalar* (seeds + step*M) and the append is a single
            dynamic_update_slice.
  evals   — number of similarity evaluations (the paper's Fig-5/8a metric).

Termination matches Algorithm 1: a query is done when every entry of its pool
is checked; the loop exits when all queries are done or ``max_steps`` is hit.

Step backends (``backend=``, see DESIGN.md):
  "reference" — the loop body is ``beam_step_ref``: ~6 separate XLA ops with
                HBM round-trips between gather, score, mask and merge.
  "pallas"    — the loop body is the fused ``beam_step`` kernel: the whole
                iteration runs per query tile in VMEM.  Off-TPU the kernel
                auto-falls back to interpret mode (bit-identical ids, CPU
                speed), so the same code path is testable everywhere.
Both backends share seeding/termination and return identical result ids.

Storage backends (``storage=``, see DESIGN.md §8): with ``storage="int8"``
the walk scores against the quantized item store — symmetric per-row int8
codes + fp32 scales, 4x less HBM streamed per step — and the final candidate
pool is re-scored EXACTLY in fp32 before the top-k is returned (asymmetric
rerank: approximate walk, exact refine).  Both step backends implement the
same quantized-score convention, so reference and pallas int8 walks also
return identical ids.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.graph import GraphIndex
from repro.core.similarity import gather_scores
# Safe non-lazy import: repro.obs depends only on jax/numpy, never on
# repro.core, so the observability layer cannot cycle back here.
from repro.obs.trace import TraceContext, WalkTrace, walk_trace
from repro.core.storage import (
    STORAGE_BACKENDS,
    ItemStore,
    quantize_items,
    store_scores,
)

NEG_INF = jnp.float32(-jnp.inf)

STEP_BACKENDS = ("reference", "pallas")


class SearchResult(NamedTuple):
    ids: jax.Array      # [B, k] int32, -1 padded
    scores: jax.Array   # [B, k] fp32
    evals: jax.Array    # [B] int32 similarity-evaluation counts
    steps: jax.Array    # [] int32 loop iterations executed
    visited: jax.Array  # [B, V] int32 every scored id (-1 padded), Fig-5 data
    dead_evals: Optional[jax.Array] = None  # [B] int32 evaluations spent on
    #   tombstoned nodes (mutation churn-health signal; None without live=)
    trace: Optional[WalkTrace] = None  # walk telemetry (obs/trace.py); None
    #   unless a TraceContext was passed — and then computed post-loop from
    #   ``visited``, so the walk itself is untouched either way


class _State(NamedTuple):
    pool_ids: jax.Array      # [B, L]
    pool_scores: jax.Array   # [B, L]
    pool_checked: jax.Array  # [B, L] bool
    visited: jax.Array       # [B, V]
    evals: jax.Array         # [B]
    dead_evals: jax.Array    # [B]
    done: jax.Array          # [B] bool
    step: jax.Array          # []


def _dedup_ids(ids: jax.Array) -> jax.Array:
    """Replace duplicate ids within each row by -1 (keeps first occurrence
    in sorted order; order does not matter for seeding)."""
    s = jnp.sort(ids, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[..., :1], dtype=bool), s[..., 1:] == s[..., :-1]],
        axis=-1,
    )
    return jnp.where(dup, -1, s)


def make_step_fn(
    backend: str,
    queries: jax.Array,
    adj: jax.Array,
    items: jax.Array,
    *,
    score_fn=gather_scores,
    interpret: Optional[bool] = None,
    store: Optional[ItemStore] = None,
    live: Optional[jax.Array] = None,
):
    """Resolve ``backend`` to a step function over the per-query walk state:

        step_fn(pool_ids, pool_scores, pool_checked, visited, done)
            -> StepResult

    This is the extension point every walk kernel slots into — later fused
    kernels (distance pruning, batched build) register the same shape.
    ``interpret=None`` auto-falls back to Pallas interpret mode off-TPU.
    With ``store`` given (the int8 storage backend), steps score against the
    quantized codes instead of ``items`` — via ``quant_score_ref`` on the
    reference path and the kernel's int8 row-gather path on pallas.
    With ``live`` given (the mutation layer's tombstone mask, DESIGN.md §9),
    both backends additionally count per-step tombstone evaluations
    (``StepResult.n_dead``); traversal itself is mask-blind.
    """
    # Deferred import: kernels.beam_step.ref reuses core.similarity, so a
    # module-level import here would be circular through core/__init__.
    from repro.kernels.beam_step import beam_step, beam_step_ref

    if backend == "reference":
        step_score_fn = score_fn if store is None else _store_score_fn(store)

        def step_fn(pool_ids, pool_scores, pool_checked, visited, done):
            return beam_step_ref(
                pool_ids, pool_scores, pool_checked, visited, done,
                queries, adj, items, score_fn=step_score_fn, live=live,
            )

        return step_fn

    if backend == "pallas":
        if score_fn is not gather_scores:
            raise ValueError(
                "backend='pallas' scores with the fused kernel's inner "
                "product and cannot honor a custom score_fn; use "
                "backend='reference' for custom similarities"
            )
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        # Pre-pad once, outside the while_loop, so the per-step pads inside
        # the jit'd kernel wrapper fold away (zero-padding keeps fp32 inner
        # products bit-identical).  _round_up is the kernel wrapper's own
        # lane-width rule, so the two stay in lockstep.
        from repro.kernels.beam_step.ops import _round_up

        d = items.shape[1]
        dp = _round_up(d, 128)
        q_pad = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, dp - d)))
        if store is None:
            x_pad = jnp.pad(items.astype(jnp.float32), ((0, 0), (0, dp - d)))
            scales = None
        else:
            x_pad = jnp.pad(store.codes.astype(jnp.int8), ((0, 0), (0, dp - d)))
            scales = store.scales

        live_col = None if live is None else live.astype(jnp.int32)

        def step_fn(pool_ids, pool_scores, pool_checked, visited, done):
            return beam_step(
                pool_ids, pool_scores, pool_checked, visited, done,
                q_pad, adj, x_pad, scales, live_col, interpret=interpret,
            )

        return step_fn

    raise ValueError(f"backend must be one of {STEP_BACKENDS}, got {backend!r}")


def _store_score_fn(store: ItemStore):
    """``storage.store_scores`` as a ``score_fn`` — closes over the store
    and ignores the fp32 items the walk passes positionally."""

    def qscore(queries, _items, ids):
        return store_scores(queries, store, ids)

    return qscore


def beam_search(
    graph: GraphIndex,
    queries: jax.Array,
    init_ids: jax.Array,
    *,
    pool_size: int,
    max_steps: int,
    k: int,
    score_fn=gather_scores,
    backend: str = "reference",
    interpret: Optional[bool] = None,
    storage: str = "f32",
    store: Optional[ItemStore] = None,
    valid: Optional[jax.Array] = None,
    live: Optional[jax.Array] = None,
    trace: Optional[TraceContext] = None,
) -> SearchResult:
    """Run the batched walk.

    graph:    GraphIndex over [N, d] items with [N, M] adjacency.
    queries:  [B, d].
    init_ids: [B, S] int32 seed ids (-1 padded, duplicates allowed).  For
              plain ip-NSW this is the entry vertex; for ip-NSW+ it is the
              ip-graph neighborhood of the angular search results (Alg 3).
    backend:  "reference" | "pallas" — which step_fn runs the loop body.
    storage:  "f32" | "int8" — which item representation the walk streams
              (STORAGE_BACKENDS, DESIGN.md §8).  "int8" walks on quantized
              scores from ``store`` (derived from ``graph.items`` here when
              not supplied — index classes pass their cached store) and
              re-scores the final pool exactly in fp32 before the top-k cut,
              so returned scores are always exact inner products.
    valid:    optional [B] bool — the bucket-padding mask the serving loop
              (launch/serve_loop.py) uses to run a partial batch inside a
              fixed-size compiled program.  Pad rows (``valid=False``) are
              born done with an empty pool: they take no walk steps, spend
              zero evals, and return ids=-1 / scores=-inf.  Because every
              per-step operation is row-wise and done rows are frozen by the
              step backends, a live row's result is bit-identical to the
              same query in a batch of any other size (the
              padding-equivalence pin in tests/test_serve_loop.py).  Pad
              query rows are ignored but must hold finite values.
    live:     optional [N] bool — the mutation layer's tombstone mask
              (core/mutation.py, DESIGN.md §9).  Walks traverse THROUGH dead
              nodes (they keep their true scores in the pool and their
              adjacency rows keep routing — tombstoning the large-norm hubs
              must not sever navigability), but dead ids are masked out of
              the final top-k cut, so they are never returned.  Both step
              backends also count tombstone evaluations into
              ``SearchResult.dead_evals``.  ``None`` (the default) is the
              frozen-index fast path: bit-identical to the pre-mutation
              behavior, no extra gathers.
    trace:    optional TraceContext (obs/trace.py).  When given, the result
              carries ``SearchResult.trace``: the first ``trace_cap``
              visited ids + walk scores per query, the per-norm-band eval
              histogram, hub-hit counts and steps-to-converge.  Computed
              AFTER the walk loop from the ``visited`` ring buffer inside
              the same program, so the walk itself (and every other result
              field) is bit-identical with tracing on or off; all trace
              shapes are static, so toggling None <-> ctx is one extra
              compile per dispatch shape and zero steady-state recompiles
              (both pinned in tests/test_obs.py).
    """
    # Validate eagerly, before seeding does any work: a typo'd backend must
    # not survive until make_step_fn resolves it mid-trace (by which point a
    # build driver may have minutes of committed batches behind it).
    if backend not in STEP_BACKENDS:
        raise ValueError(
            f"backend must be one of {STEP_BACKENDS}, got {backend!r}"
        )
    if storage not in STORAGE_BACKENDS:
        raise ValueError(
            f"storage must be one of {STORAGE_BACKENDS}, got {storage!r}"
        )
    adj, items = graph.adj, graph.items
    if trace is not None and trace.band_ids.shape[0] != adj.shape[0]:
        raise ValueError(
            f"trace context covers {trace.band_ids.shape[0]} nodes but the "
            f"graph has {adj.shape[0]} — rebuild it with make_trace_context "
            "on this index's norms (mutable indexes: the full capacity)"
        )
    if storage == "int8":
        if score_fn is not gather_scores:
            raise ValueError(
                "storage='int8' scores with the quantized store's inner "
                "product and cannot honor a custom score_fn; use "
                "storage='f32' for custom similarities"
            )
        if store is None:
            store = quantize_items(items)
    else:
        store = None
    # Seeds are scored with the SAME scorer the walk steps use, so the pool
    # ordering stays consistent across the whole walk.
    walk_score_fn = score_fn if store is None else _store_score_fn(store)
    B, S = init_ids.shape
    M = adj.shape[1]
    L = pool_size
    V = S + max_steps * M  # visited capacity — exact, no clipping needed

    if live is not None:
        live = live.astype(bool)

    init_ids = _dedup_ids(init_ids)
    if valid is not None:
        # Pad rows lose their seeds entirely: all-(-1) seeds give an
        # all-checked, -inf pool below, and done=True keeps every step
        # backend from ever advancing them.
        init_ids = jnp.where(valid[:, None].astype(bool), init_ids, -1)
    valid0 = init_ids >= 0
    scores0 = jnp.where(
        valid0, walk_score_fn(queries, items, init_ids), NEG_INF
    )
    evals0 = valid0.sum(axis=-1).astype(jnp.int32)
    if live is None:
        dead0 = jnp.zeros_like(evals0)
    else:
        dead0 = (valid0 & ~live[jnp.maximum(init_ids, 0)]).sum(
            axis=-1).astype(jnp.int32)

    # Seed pool = top-L of the seeds (sorted desc; empty slots are checked).
    top0, idx0 = jax.lax.top_k(scores0, min(L, S))
    ids0 = jnp.take_along_axis(init_ids, idx0, axis=-1)
    pad = L - ids0.shape[1]
    if pad > 0:
        ids0 = jnp.pad(ids0, ((0, 0), (0, pad)), constant_values=-1)
        top0 = jnp.pad(top0, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    pool_ids = ids0.astype(jnp.int32)
    pool_scores = top0.astype(jnp.float32)
    pool_checked = pool_ids < 0  # empty slots can never be selected

    visited = jnp.full((B, V), -1, jnp.int32)
    visited = jax.lax.dynamic_update_slice(visited, init_ids.astype(jnp.int32), (0, 0))

    state = _State(
        pool_ids=pool_ids,
        pool_scores=pool_scores,
        pool_checked=pool_checked,
        visited=visited,
        evals=evals0,
        dead_evals=dead0,
        done=(jnp.zeros((B,), bool) if valid is None
              else ~valid.astype(bool)),
        step=jnp.zeros((), jnp.int32),
    )

    step_fn = make_step_fn(
        backend, queries, adj, items, score_fn=score_fn, interpret=interpret,
        store=store, live=live,
    )

    def cond(st: _State):
        return (st.step < max_steps) & jnp.any(~st.done)

    def body(st: _State) -> _State:
        res = step_fn(st.pool_ids, st.pool_scores, st.pool_checked,
                      st.visited, st.done)
        visited = jax.lax.dynamic_update_slice(
            st.visited, res.nbr_ids, (0, S + st.step * M)
        )
        n_dead = res.n_dead if res.n_dead is not None else 0
        return _State(
            pool_ids=res.pool_ids,
            pool_scores=res.pool_scores,
            pool_checked=res.pool_checked,
            visited=visited,
            evals=st.evals + res.n_scored,
            dead_evals=st.dead_evals + n_dead,
            done=res.done,
            step=st.step + 1,
        )

    final = jax.lax.while_loop(cond, body, state)
    dead_evals = final.dead_evals if live is not None else None
    # Telemetry is derived from the finished ring buffer — the loop above
    # never saw the trace context, which is what makes trace=None trivially
    # bit-identical.  Scored with walk_score_fn so int8 traces report the
    # quantized scores the walk actually ranked by.
    tr = None if trace is None else walk_trace(
        trace, final.visited, queries, items, walk_score_fn,
        seeds=S, degree=M,
    )

    if store is not None:
        # Exact fp32 rerank of the final ef-pool (asymmetric refine,
        # DESIGN.md §8): the quantized walk chose WHICH ~L candidates
        # survive; the fp32 pass decides their order and the top-k cut, so
        # int8's score error only costs recall when a true top-k item never
        # entered the pool at all.  L gathered fp32 rows per query — noise
        # next to the walk's streaming.  Walk ``evals`` stay the quantized
        # counts (the paper's Fig-5/8a metric counts pool insertions, and
        # the rerank re-scores rows the walk already evaluated).
        pool_ids = final.pool_ids
        keep = pool_ids >= 0
        if live is not None:
            # Tombstones routed the walk but may not be returned: fold the
            # live gather into the rerank's existing mask.
            keep &= live[jnp.maximum(pool_ids, 0)]
        exact = jnp.where(
            keep, score_fn(queries, items, pool_ids), NEG_INF
        )
        vals, sel = jax.lax.top_k(exact, k)
        ids = jnp.take_along_axis(pool_ids, sel, axis=-1)
        return SearchResult(
            ids=jnp.where(vals > NEG_INF, ids, -1),
            scores=vals,
            evals=final.evals,
            steps=final.step,
            visited=final.visited,
            dead_evals=dead_evals,
            trace=tr,
        )

    if live is not None:
        # f32 path with tombstones: the pool is sorted desc, so a masked
        # top-k (stable for ties — top_k prefers the lower index) returns
        # the best k LIVE pool entries in their existing order.  The
        # live=None branch below stays the untouched pre-mutation slice, so
        # frozen indexes keep their pinned bit-exact behavior.
        pool_ids = final.pool_ids
        keep = (pool_ids >= 0) & live[jnp.maximum(pool_ids, 0)]
        masked = jnp.where(keep, final.pool_scores, NEG_INF)
        vals, sel = jax.lax.top_k(masked, k)
        ids = jnp.take_along_axis(pool_ids, sel, axis=-1)
        return SearchResult(
            ids=jnp.where(vals > NEG_INF, ids, -1),
            scores=vals,
            evals=final.evals,
            steps=final.step,
            visited=final.visited,
            dead_evals=dead_evals,
            trace=tr,
        )

    return SearchResult(
        ids=final.pool_ids[:, :k],
        scores=final.pool_scores[:, :k],
        evals=final.evals,
        steps=final.step,
        visited=final.visited,
        trace=tr,
    )
