"""Batched graph-walk search (paper Algorithm 1), TPU-native.

The CPU reference implementation walks one query at a time with a priority
queue and a hash-set visited list.  Here B queries advance in lock-step inside
a single ``lax.while_loop``; every per-step operation is a dense gather,
matmul or top-k, so the walk lowers to MXU/VPU work and shards with pjit.

Per-query state:
  pool    — fixed-size candidate pool (ids, scores, checked), kept sorted by
            score descending (paper's candidate pool C with size l).
  visited — append-only ring buffer of every id that has been scored.  Dedup
            is a vectorized id-equality mask against this buffer; because each
            step appends exactly M slots for every query, the write offset is
            a *scalar* (seeds + step*M) and the append is a single
            dynamic_update_slice.
  evals   — number of similarity evaluations (the paper's Fig-5/8a metric).

Termination matches Algorithm 1: a query is done when every entry of its pool
is checked; the loop exits when all queries are done or ``max_steps`` is hit.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.graph import GraphIndex
from repro.core.similarity import gather_scores

NEG_INF = jnp.float32(-jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array      # [B, k] int32, -1 padded
    scores: jax.Array   # [B, k] fp32
    evals: jax.Array    # [B] int32 similarity-evaluation counts
    steps: jax.Array    # [] int32 loop iterations executed
    visited: jax.Array  # [B, V] int32 every scored id (-1 padded), Fig-5 data


class _State(NamedTuple):
    pool_ids: jax.Array      # [B, L]
    pool_scores: jax.Array   # [B, L]
    pool_checked: jax.Array  # [B, L] bool
    visited: jax.Array       # [B, V]
    evals: jax.Array         # [B]
    done: jax.Array          # [B] bool
    step: jax.Array          # []


def _dedup_ids(ids: jax.Array) -> jax.Array:
    """Replace duplicate ids within each row by -1 (keeps first occurrence
    in sorted order; order does not matter for seeding)."""
    s = jnp.sort(ids, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[..., :1], dtype=bool), s[..., 1:] == s[..., :-1]],
        axis=-1,
    )
    return jnp.where(dup, -1, s)


def beam_search(
    graph: GraphIndex,
    queries: jax.Array,
    init_ids: jax.Array,
    *,
    pool_size: int,
    max_steps: int,
    k: int,
    score_fn=gather_scores,
) -> SearchResult:
    """Run the batched walk.

    graph:    GraphIndex over [N, d] items with [N, M] adjacency.
    queries:  [B, d].
    init_ids: [B, S] int32 seed ids (-1 padded, duplicates allowed).  For
              plain ip-NSW this is the entry vertex; for ip-NSW+ it is the
              ip-graph neighborhood of the angular search results (Alg 3).
    """
    adj, items = graph.adj, graph.items
    B, S = init_ids.shape
    M = adj.shape[1]
    L = pool_size
    V = S + max_steps * M  # visited capacity — exact, no clipping needed

    init_ids = _dedup_ids(init_ids)
    valid0 = init_ids >= 0
    scores0 = jnp.where(valid0, score_fn(queries, items, init_ids), NEG_INF)
    evals0 = valid0.sum(axis=-1).astype(jnp.int32)

    #

    # Seed pool = top-L of the seeds (sorted desc; empty slots are checked).
    top0, idx0 = jax.lax.top_k(scores0, min(L, S))
    ids0 = jnp.take_along_axis(init_ids, idx0, axis=-1)
    pad = L - ids0.shape[1]
    if pad > 0:
        ids0 = jnp.pad(ids0, ((0, 0), (0, pad)), constant_values=-1)
        top0 = jnp.pad(top0, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    pool_ids = ids0.astype(jnp.int32)
    pool_scores = top0.astype(jnp.float32)
    pool_checked = pool_ids < 0  # empty slots can never be selected

    visited = jnp.full((B, V), -1, jnp.int32)
    visited = jax.lax.dynamic_update_slice(visited, init_ids.astype(jnp.int32), (0, 0))

    state = _State(
        pool_ids=pool_ids,
        pool_scores=pool_scores,
        pool_checked=pool_checked,
        visited=visited,
        evals=evals0,
        done=jnp.zeros((B,), bool),
        step=jnp.zeros((), jnp.int32),
    )

    rows = jnp.arange(B)

    def cond(st: _State):
        return (st.step < max_steps) & jnp.any(~st.done)

    def body(st: _State) -> _State:
        unchecked = (~st.pool_checked) & (st.pool_ids >= 0)
        has_unchecked = unchecked.any(axis=-1)
        done = st.done | ~has_unchecked
        upd = ~done  # queries that take a step this iteration

        # Pool is sorted desc => first unchecked slot is the best unchecked.
        cur_slot = jnp.argmax(unchecked, axis=-1)
        cur_id = st.pool_ids[rows, cur_slot]
        cur_id = jnp.where(upd, cur_id, graph.entry)

        checked = st.pool_checked | (
            jax.nn.one_hot(cur_slot, L, dtype=bool) & upd[:, None]
        )

        nbrs = adj[jnp.maximum(cur_id, 0)]  # [B, M]
        valid = (nbrs >= 0) & upd[:, None]
        seen = (nbrs[:, :, None] == st.visited[:, None, :]).any(axis=-1)
        valid &= ~seen

        nbr_scores = score_fn(queries, items, nbrs)
        nbr_scores = jnp.where(valid, nbr_scores, NEG_INF)
        nbr_ids = jnp.where(valid, nbrs, -1).astype(jnp.int32)
        evals = st.evals + valid.sum(axis=-1).astype(jnp.int32)

        visited = jax.lax.dynamic_update_slice(
            st.visited, nbr_ids, (0, S + st.step * M)
        )

        cand_ids = jnp.concatenate([st.pool_ids, nbr_ids], axis=-1)
        cand_scores = jnp.concatenate([st.pool_scores, nbr_scores], axis=-1)
        cand_checked = jnp.concatenate([checked, ~valid], axis=-1)

        new_scores, sel = jax.lax.top_k(cand_scores, L)
        new_ids = jnp.take_along_axis(cand_ids, sel, axis=-1)
        new_checked = jnp.take_along_axis(cand_checked, sel, axis=-1)

        return _State(
            pool_ids=new_ids,
            pool_scores=new_scores,
            pool_checked=new_checked,
            visited=visited,
            evals=evals,
            done=done,
            step=st.step + 1,
        )

    final = jax.lax.while_loop(cond, body, state)

    return SearchResult(
        ids=final.pool_ids[:, :k],
        scores=final.pool_scores[:, :k],
        evals=final.evals,
        steps=final.step,
        visited=final.visited,
    )
