"""Norm-bias analysis utilities (paper §2, Figures 1–3, Theorems 1–2).

Everything here is host-side analysis used by the benchmarks; the search path
never calls into this module.
"""
from __future__ import annotations

import numpy as np
from jax.scipy.special import erf


# ---------------------------------------------------------------------------
# Norm groups (Figures 1, 4, 5)
# ---------------------------------------------------------------------------


def norm_group_of(norms: np.ndarray, n_groups: int = 20) -> np.ndarray:
    """Rank-based norm group per item: 0 = top ``100/n_groups`` % in norm,
    1 = next slice, ... ``n_groups-1`` = smallest norms.

    Matches the paper's partition "items ranking top 5% in norm", "top
    20%-25%", ... for ``n_groups=20``.
    """
    norms = np.asarray(norms)
    n = norms.shape[0]
    # rank 0 = largest norm
    rank = np.empty(n, dtype=np.int64)
    rank[np.argsort(-norms, kind="stable")] = np.arange(n)
    group = (rank * n_groups) // n
    return group.astype(np.int32)


def group_occupancy(
    result_ids: np.ndarray, groups: np.ndarray, n_groups: int = 20
) -> np.ndarray:
    """Fraction of the (flattened, duplicates-allowed) result set that falls
    in each norm group — the quantity plotted in Figure 1 / Figure 5."""
    ids = np.asarray(result_ids).reshape(-1)
    ids = ids[ids >= 0]
    counts = np.bincount(groups[ids], minlength=n_groups).astype(np.float64)
    total = counts.sum()
    return counts / max(total, 1.0)


def top_group_share(result_ids: np.ndarray, norms: np.ndarray, pct: float = 5.0) -> float:
    """Share of results occupied by items ranking in the top ``pct`` % by
    norm (the headline 87.5–100 % numbers of the paper)."""
    n_groups = int(round(100.0 / pct))
    groups = norm_group_of(norms, n_groups)
    return float(group_occupancy(result_ids, groups, n_groups)[0])


def tailing_factor(norms: np.ndarray) -> float:
    """TF = 95th-percentile norm / median norm (paper §5, Fig 8c)."""
    norms = np.asarray(norms)
    return float(np.percentile(norms, 95) / np.median(norms))


def in_degree_by_group(
    in_deg: np.ndarray, groups: np.ndarray, n_groups: int = 20
) -> np.ndarray:
    """Average in-degree per norm group, normalized by dataset average
    (Figure 4's y-axis is the raw average; we report both)."""
    out = np.zeros(n_groups)
    for g in range(n_groups):
        m = groups == g
        out[g] = in_deg[m].mean() if m.any() else 0.0
    return out


# ---------------------------------------------------------------------------
# Theorem 1 — P[qx >= qy | qx >= 0, qy >= 0] for x_i ~ N(0, alpha), y_i ~ N(0,1)
# ---------------------------------------------------------------------------


def theorem1_probability(alpha: float, n_grid: int = 8192) -> float:
    """Numerical evaluation of the paper's Theorem-1 double integral:

        P = 2 / (pi * sqrt(alpha)) * int_0^inf e^{-a^2/(2 alpha)}
                                       int_0^a e^{-b^2/2} db da

    The inner integral is sqrt(pi/2) * erf(a / sqrt(2)).
    Sanity: alpha = 1  ->  P = 0.5 exactly.
    """
    alpha = float(alpha)
    hi = 12.0 * max(np.sqrt(alpha), 1.0)
    a = np.linspace(0.0, hi, n_grid)
    inner = np.sqrt(np.pi / 2.0) * np.asarray(erf(a / np.sqrt(2.0)))
    integrand = np.exp(-(a**2) / (2.0 * alpha)) * inner
    val = np.trapezoid(integrand, a)
    return float(2.0 / (np.pi * np.sqrt(alpha)) * val)


def cardinality_win_probability(alpha: float, m: int) -> float:
    """Paper §2 cardinality argument: probability that a modest-norm item
    beats all ``m`` items whose norm is ``sqrt(alpha)`` times larger,
    assuming independence: (1 - P(alpha))^m with P from Theorem 1."""
    p_single = theorem1_probability(alpha)
    return float((1.0 - p_single) ** m)


# ---------------------------------------------------------------------------
# Theorem 2 — x.z | y.z = gamma  ~  N(gamma*beta*|x|/|y|, |x|^2 (1-beta^2))
# ---------------------------------------------------------------------------


def theorem2_conditional(
    beta: float, gamma: float, x_norm: float, y_norm: float
) -> tuple[float, float]:
    """Mean and std of x.z given y.z = gamma under Theorem 2's model."""
    mean = gamma * beta * x_norm / y_norm
    std = x_norm * np.sqrt(max(1.0 - beta**2, 0.0))
    return float(mean), float(std)
