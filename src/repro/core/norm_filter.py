"""Norm-filtered MIPS index — a BEYOND-PAPER optimization that
operationalizes the paper's own Figure-1 finding: items ranking top-p% in
norm hold 87.5-100% of true top-10 MIPS results, so indexing ONLY the
top-``keep_frac`` fraction by norm bounds the achievable recall by the
ground-truth occupancy of that slice while cutting index memory, build time
and walk length proportionally.

This composes with any inner index (ip-NSW or ip-NSW+).  The measured
recall-vs-keep_frac trade-off lives in benchmarks/beyond_paper.py (the
``beyond_norm_filter`` rows); on heavy-tailed norm profiles keep_frac=0.25
retains ~99% of achievable recall at ~4x less index.  Composing with
``storage="int8"`` stacks the two reductions: keep_frac x 4 less item
memory than the full-catalog fp32 index.

Serving note: the filter also shrinks the fault domain — the sharded index
(core/distributed.py) over the filtered subset has 1/keep_frac fewer shards
for the same shard size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ipnsw import IpNSW
from repro.core.ipnsw_plus import IpNSWPlus, PlusResult


@dataclass
class NormFilteredIndex:
    keep_frac: float = 0.25
    plus: bool = True
    max_degree: int = 16
    ef_construction: int = 64
    insert_batch: int = 256
    # The four backend axes (docs/ARCHITECTURE.md), forwarded verbatim to
    # the inner IpNSW / IpNSWPlus — the filter is a pure id-remapping shell.
    backend: str = "reference"
    build_backend: str = "host"
    commit_backend: str = "reference"
    commit_tile: object = "auto"   # int | "auto" (DESIGN.md §7)
    storage: str = "f32"
    inner: object = field(default=None)
    global_ids: Optional[np.ndarray] = None

    def build(self, items: jax.Array, progress: bool = False):
        items = jnp.asarray(items)
        n = items.shape[0]
        keep = max(int(n * self.keep_frac), 16)
        norms = np.linalg.norm(np.asarray(items), axis=1)
        order = np.argsort(-norms)[:keep].astype(np.int32)
        # keep insertion order random-ish (sorted-by-norm insertion would
        # bias early-graph connectivity); shuffle deterministically
        rng = np.random.default_rng(0)
        rng.shuffle(order)
        self.global_ids = order
        sub = items[jnp.asarray(order)]
        cls = IpNSWPlus if self.plus else IpNSW
        self.inner = cls(
            max_degree=self.max_degree,
            ef_construction=self.ef_construction,
            insert_batch=self.insert_batch,
            backend=self.backend,
            build_backend=self.build_backend,
            commit_backend=self.commit_backend,
            commit_tile=self.commit_tile,
            storage=self.storage,
        ).build(sub, progress=progress)
        return self

    def search(self, queries: jax.Array, k: int = 10, ef: int = 64, **kw):
        assert self.inner is not None, "call build() first"
        res = self.inner.search(queries, k=k, ef=ef, **kw)
        gids = jnp.asarray(self.global_ids)
        mapped = jnp.where(res.ids >= 0, gids[jnp.maximum(res.ids, 0)], -1)
        return res._replace(ids=mapped)
