"""Deprecated alias — the recall helpers moved to :mod:`repro.obs.recall`.

This module name now collides conceptually with the observability layer's
metrics *registry* (``repro.obs.registry``), so the quality metrics live in
``repro.obs`` and this shim re-exports them for old imports.  New code
should import from ``repro.obs`` (or ``repro.core``, which re-exports).
"""
from __future__ import annotations

import warnings

from repro.obs.recall import recall_at_k, recall_curve

__all__ = ["recall_at_k", "recall_curve"]

warnings.warn(
    "repro.core.metrics moved to repro.obs.recall; import recall_at_k / "
    "recall_curve from repro.obs (or repro.core) instead",
    DeprecationWarning,
    stacklevel=2,
)
