"""Quantized item store — the ``storage=`` backend axis (DESIGN.md §8).

The walk and the exact-MIPS scan are HBM-bound at ``N*d*4`` bytes of fp32
item streaming (kernel_bench's roofline).  Storing the catalog as symmetric
per-row int8 codes + one fp32 scale per row cuts that traffic ~4x and lets
~4x larger catalogs fit per device.

Per-row scales (not one global scale) because of the paper's norm bias: the
large-norm hubs that dominate walk computation span a heavy norm tail
(Figure 2), and a single global scale would crush the small-norm mass into a
handful of code levels — the same observation that motivates norm partitioning
in Norm-Ranging LSH (Yan et al. 2018).  The quantizer is exact about signs
and monotone per row, and the residual score error is repaired by an
asymmetric exact fp32 rerank of the final candidate pool (quantized walk,
fp32 top-k refine — the lightweight-index design of ProMIPS, Song et al.
2021); ``core.search.beam_search`` owns that rerank.

Contract (see DESIGN.md §8):
  * ``scale_i = max(|x_i|) / 127`` (clamped away from 0), ``codes_i =
    round(x_i / scale_i)`` in [-127, 127] — symmetric, zero maps to zero.
  * quantized score convention everywhere (ref oracle, fused kernels):
    ``s~(q, i) = (q . codes_i) * scale_i`` — the dot runs in fp32 over the
    cast codes, then ONE multiply per score.  Every backend implements this
    exact op order so reference and Pallas walks stay bit-identical.
  * the graph is built on fp32 items and the store is derived once from the
    frozen items post-build (quantizing before construction would bake code
    error into edge selection); search-time storage is a per-call knob.

``STORAGE_BACKENDS`` is the third orthogonal backend axis next to
``backend=`` (walk step), ``build_backend=`` (insertion driver) and
``commit_backend=`` (reverse-link merge).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

STORAGE_BACKENDS = ("f32", "int8")

_EPS = 1e-12


class ItemStore(NamedTuple):
    """Symmetric per-row int8 codes + fp32 dequantization scales.

    codes:  [..., N, d] int8 in [-127, 127].
    scales: [..., N] fp32; ``items ~= codes * scales[..., None]``.

    A pytree of arrays only, so it vmaps over a leading shard axis
    (core/distributed.py) and passes through jit boundaries; the ``storage``
    knob itself travels separately as a static string, like the other
    backend knobs.
    """

    codes: jax.Array
    scales: jax.Array


def validate_storage(storage: str) -> None:
    """Eager knob validation — same style as the backend/build_backend/
    commit_backend checks: a typo'd storage must fail before any build or
    trace work starts."""
    if storage not in STORAGE_BACKENDS:
        raise ValueError(
            f"storage must be one of {STORAGE_BACKENDS}, got {storage!r}"
        )


def quantize_items(items: jax.Array) -> ItemStore:
    """[..., N, d] fp32 -> symmetric per-row int8 store.

    All-zero rows (e.g. the tail-shard zero padding in distributed.py) get
    the clamped minimum scale and all-zero codes, so their quantized scores
    stay exactly 0.0 — identical to their fp32 scores."""
    items = jnp.asarray(items, jnp.float32)
    amax = jnp.max(jnp.abs(items), axis=-1)
    scales = jnp.maximum(amax, _EPS) / 127.0
    codes = jnp.clip(
        jnp.round(items / scales[..., None]), -127.0, 127.0
    ).astype(jnp.int8)
    return ItemStore(codes=codes, scales=scales.astype(jnp.float32))


def dequantize(store: ItemStore) -> jax.Array:
    """Reconstruct fp32 items; per-element error is bounded by scale/2."""
    return store.codes.astype(jnp.float32) * store.scales[..., None]


def make_store(items: jax.Array, storage: str) -> Optional[ItemStore]:
    """Resolve the storage knob: ``None`` for the fp32 fast path (no copy,
    the GraphIndex items ARE the store), a quantized store for "int8"."""
    validate_storage(storage)
    if storage == "f32":
        return None
    return quantize_items(items)


def update_store_rows(
    store: ItemStore, rows: jax.Array, new_items: jax.Array
) -> ItemStore:
    """Requantize a batch of rows in place (mutation-layer upsert sync).

    ``rows`` may contain out-of-range ids (the mutation layer's pad-row
    convention, ``rows == N``) — those scatter-drop, mirroring how the
    fp32 item updates drop them.  Deliberately NOT jitted: fusing the
    max/divide of ``quantize_items`` changes its rounding by one ULP, and
    the mutation layer pins the synced store bit-identical to an eager
    from-scratch requantization (tests/test_mutation.py)."""
    part = quantize_items(new_items)
    return ItemStore(
        codes=store.codes.at[rows].set(part.codes, mode="drop"),
        scales=store.scales.at[rows].set(part.scales, mode="drop"),
    )


def store_scores(
    queries: jax.Array, store: ItemStore, ids: jax.Array
) -> jax.Array:
    """Gathered quantized scores ``(q . codes[id]) * scales[id]`` with -1 ids
    masked to -inf — the reference scorer the quantized walk plugs into
    ``beam_step_ref``.  Delegates to the quant_score oracle so the scoring
    convention has exactly one definition."""
    from repro.kernels.quant_score import quant_score_ref

    return quant_score_ref(queries, store.codes, store.scales, ids)
