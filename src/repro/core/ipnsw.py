"""ip-NSW (Morozov & Babenko 2018) — the paper's baseline.

NSW built and searched with the raw inner product as similarity.  This is the
algorithm whose norm bias §3 of the paper analyses.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.build import build_graph
from repro.core.graph import GraphIndex
from repro.core.search import SearchResult, beam_search
from repro.core.similarity import Similarity


@functools.partial(
    jax.jit, static_argnames=("pool_size", "max_steps", "k", "backend")
)
def _search(
    graph: GraphIndex,
    queries,
    *,
    pool_size: int,
    max_steps: int,
    k: int,
    backend: str = "reference",
):
    b = queries.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    return beam_search(
        graph, queries, init, pool_size=pool_size, max_steps=max_steps, k=k,
        backend=backend,
    )


@dataclass
class IpNSW:
    """Inner-product NSW index.

    build parameters mirror the paper: ``max_degree`` = M, ``ef_construction``
    = candidate-pool size l used during insertion.  ``backend`` selects the
    walk step implementation ("reference" | "pallas", see search.py);
    ``build_backend`` selects the insertion driver ("host" | "scan", see
    build.BUILD_BACKENDS); ``commit_backend`` selects the reverse-link merge
    kernel ("reference" | "pallas", see build.COMMIT_BACKENDS).
    """

    max_degree: int = 16
    ef_construction: int = 64
    insert_batch: int = 128
    reverse_links: bool = True
    backend: str = "reference"
    build_backend: str = "host"
    commit_backend: str = "reference"
    graph: Optional[GraphIndex] = None

    def build(self, items: jax.Array, progress: bool = False) -> "IpNSW":
        self.graph = build_graph(
            items,
            similarity=Similarity.INNER_PRODUCT,
            max_degree=self.max_degree,
            ef_construction=self.ef_construction,
            insert_batch=self.insert_batch,
            reverse_links=self.reverse_links,
            backend=self.backend,
            build_backend=self.build_backend,
            commit_backend=self.commit_backend,
            progress=progress,
        )
        return self

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        ef: int = 64,
        max_steps: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> SearchResult:
        assert self.graph is not None, "call build() first"
        steps = max_steps if max_steps is not None else 2 * ef
        return _search(
            self.graph, queries, pool_size=max(ef, k), max_steps=steps, k=k,
            backend=backend if backend is not None else self.backend,
        )
