"""ip-NSW (Morozov & Babenko 2018) — the paper's baseline.

NSW built and searched with the raw inner product as similarity.  This is the
algorithm whose norm bias §3 of the paper analyses.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.build import build_graph
from repro.core.graph import GraphIndex
from repro.core.search import SearchResult, beam_search
from repro.core.similarity import Similarity
from repro.core.storage import ItemStore, make_store, validate_storage


@functools.partial(
    jax.jit,
    static_argnames=("pool_size", "max_steps", "k", "backend", "storage"),
)
def _search(
    graph: GraphIndex,
    queries,
    store: Optional[ItemStore] = None,
    valid=None,
    live=None,
    trace=None,
    *,
    pool_size: int,
    max_steps: int,
    k: int,
    backend: str = "reference",
    storage: str = "f32",
):
    b = queries.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    return beam_search(
        graph, queries, init, pool_size=pool_size, max_steps=max_steps, k=k,
        backend=backend, storage=storage, store=store, valid=valid, live=live,
        trace=trace,
    )


@dataclass
class IpNSW:
    """Inner-product NSW index.

    build parameters mirror the paper: ``max_degree`` = M, ``ef_construction``
    = candidate-pool size l used during insertion.  ``backend`` selects the
    walk step implementation ("reference" | "pallas", see search.py);
    ``build_backend`` selects the insertion driver ("host" | "scan", see
    build.BUILD_BACKENDS); ``commit_backend`` selects the reverse-link merge
    kernel ("reference" | "pallas", see build.COMMIT_BACKENDS) and
    ``commit_tile`` its grid tiling (positive int, or "auto" for the
    norm-skew planner — kernels/commit_merge/ops.resolve_commit_tile);
    ``storage``
    selects the item representation search streams ("f32" | "int8", see
    storage.STORAGE_BACKENDS and DESIGN.md §8 — the build always runs on
    fp32 items and the quantized store is derived once post-build).
    """

    max_degree: int = 16
    ef_construction: int = 64
    insert_batch: int = 128
    reverse_links: bool = True
    backend: str = "reference"
    build_backend: str = "host"
    commit_backend: str = "reference"
    commit_tile: Union[int, str] = "auto"
    storage: str = "f32"
    graph: Optional[GraphIndex] = None
    store: Optional[ItemStore] = None

    def build(self, items: jax.Array, progress: bool = False) -> "IpNSW":
        validate_storage(self.storage)
        self.graph = build_graph(
            items,
            similarity=Similarity.INNER_PRODUCT,
            max_degree=self.max_degree,
            ef_construction=self.ef_construction,
            insert_batch=self.insert_batch,
            reverse_links=self.reverse_links,
            backend=self.backend,
            build_backend=self.build_backend,
            commit_backend=self.commit_backend,
            commit_tile=self.commit_tile,
            progress=progress,
        )
        # Derived once from the frozen fp32 items; None for the f32 path.
        self.store = make_store(self.graph.items, self.storage)
        return self

    def _resolve_store(self, storage: str) -> Optional[ItemStore]:
        """Per-call storage override: reuse the cached store, or derive and
        cache one when an f32-built index is first searched with int8."""
        validate_storage(storage)
        if storage == "f32":
            return None
        if self.store is None:
            self.store = make_store(self.graph.items, storage)
        return self.store

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        ef: int = 64,
        max_steps: Optional[int] = None,
        backend: Optional[str] = None,
        storage: Optional[str] = None,
        valid: Optional[jax.Array] = None,
        live: Optional[jax.Array] = None,
        trace=None,
    ) -> SearchResult:
        """``valid`` is the [B] bucket-padding mask (search.beam_search):
        pad rows return ids=-1 at zero eval cost, live rows are bit-identical
        to an unpadded call — the serving loop's fixed-shape entry point.
        ``live`` is the [N] tombstone mask (core/mutation.py): dead nodes
        route the walk but never appear in results.  ``trace`` is an
        optional obs.TraceContext — the result then carries
        ``SearchResult.trace`` walk telemetry at unchanged walk outputs
        (search.beam_search)."""
        assert self.graph is not None, "call build() first"
        steps = max_steps if max_steps is not None else 2 * ef
        st = storage if storage is not None else self.storage
        return _search(
            self.graph, queries, self._resolve_store(st), valid, live, trace,
            pool_size=max(ef, k), max_steps=steps, k=k,
            backend=backend if backend is not None else self.backend,
            storage=st,
        )
