"""Graph-invariant checker — the safety net under streaming mutation.

A build-once index can be validated once; a mutating one (core/mutation.py)
must be checkable at any point of its life, because a single bad commit —
an out-of-range id, an entry pointing at a tombstone, a region whose edges
all lead to dead nodes — silently degrades every search after it.  This
module states the invariants once and makes them cheap enough to run after
every test build and, opt-in, inside the serving loop.

Invariants (DESIGN.md §9):
  I1  adjacency ids are in ``[-1, capacity)`` — -1 is the empty-slot pad,
      anything else must be a real row.
  I2  edges only point at *used* slots (``id < size``): the build inserts
      ids in ascending order and mutation only reuses previously-used slots,
      so an edge into the never-used tail means a corrupted commit.
  I3  no self-loops: a node never lists itself as its own neighbor (walks
      would burn a pool slot re-scoring their own row).
  I4  the entry vertex is a used slot, and — when a live mask exists — a
      LIVE one.  A tombstoned entry still routes (walks traverse through
      dead nodes) but violates the mutation layer's contract that deletes
      re-seat the entry immediately.
  I5  live rows exist only among used slots (``live[size:]`` is all False).
  I6  the dead-edge fraction — edges from live nodes into non-live targets,
      over all edges from live nodes — stays under ``max_dead_edge_frac``.
      This is the navigability budget churn spends and ``relink`` repays;
      the threshold is the caller's degradation tolerance, not a constant.

``check_graph_invariants`` returns the violation list (empty = healthy) so
benchmarks can report without raising; ``assert_graph_invariants`` wraps it
for tests and the opt-in runtime assertion in the serving loop.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.graph import GraphIndex


def dead_edge_fraction(
    adj: np.ndarray, live: np.ndarray, size: int
) -> float:
    """Fraction of out-edges of LIVE used rows whose target is not live.

    -1 pads are not edges; edges out of dead rows don't count (dead rows are
    routing fossils — their staleness is expected and harmless)."""
    adj = np.asarray(adj)[:size]
    live = np.asarray(live, bool)
    row_live = live[:size]
    edge = (adj >= 0) & row_live[:, None]
    n_edges = int(edge.sum())
    if n_edges == 0:
        return 0.0
    dead = edge & ~live[np.maximum(adj, 0)]
    return float(dead.sum()) / n_edges


def check_graph_invariants(
    graph: GraphIndex,
    live: Optional[np.ndarray] = None,
    *,
    max_dead_edge_frac: float = 1.0,
    name: str = "graph",
) -> List[str]:
    """Validate I1–I6 on host; returns a list of violation strings."""
    adj = np.asarray(graph.adj)
    n, _ = adj.shape
    size = int(graph.size)
    entry = int(graph.entry)
    errs: List[str] = []

    if size < 0 or size > n:
        errs.append(f"{name}: size {size} outside [0, capacity={n}]")
        size = max(0, min(size, n))

    used = adj[:size]
    if used.size:
        amin, amax = int(used.min()), int(used.max())
        if amin < -1 or amax >= n:                                      # I1
            errs.append(
                f"{name}: adjacency ids span [{amin}, {amax}], "
                f"outside [-1, {n})"
            )
        elif amax >= size:                                              # I2
            bad = int(((used >= size)).sum())
            errs.append(
                f"{name}: {bad} edges point at never-used slots >= "
                f"size={size}"
            )
        rows = np.arange(size)[:, None]
        loops = int((used == rows).sum())                               # I3
        if loops:
            errs.append(f"{name}: {loops} self-loop edges")

    if size > 0 and not (0 <= entry < size):                            # I4
        errs.append(f"{name}: entry {entry} is not a used slot (< {size})")

    if live is not None:
        live = np.asarray(live, bool)
        if live.shape != (n,):
            errs.append(
                f"{name}: live mask shape {live.shape} != ({n},)"
            )
            return errs
        if size > 0 and live.any() and not live[entry]:                 # I4
            errs.append(f"{name}: entry {entry} is tombstoned")
        tail_live = int(live[size:].sum())                              # I5
        if tail_live:
            errs.append(
                f"{name}: {tail_live} live rows beyond size={size}"
            )
        frac = dead_edge_fraction(adj, live, size)                      # I6
        if frac > max_dead_edge_frac:
            errs.append(
                f"{name}: dead-edge fraction {frac:.3f} exceeds "
                f"{max_dead_edge_frac:.3f}"
            )
    return errs


def assert_graph_invariants(
    graph: GraphIndex,
    live: Optional[np.ndarray] = None,
    *,
    max_dead_edge_frac: float = 1.0,
    name: str = "graph",
) -> None:
    errs = check_graph_invariants(
        graph, live, max_dead_edge_frac=max_dead_edge_frac, name=name
    )
    if errs:
        raise AssertionError(
            "graph invariants violated:\n  " + "\n  ".join(errs)
        )
