"""Exact MIPS via linear scan — ground truth + the paper's exact-baseline
context (FEXIPRO / Maximus).

Two backends:
  * ``backend="jnp"``    — plain einsum + top_k (XLA; also the CPU oracle)
  * ``backend="pallas"`` — the tiled ``mips_topk`` Pallas kernel (TPU target,
                           interpret-mode on CPU); the `retrieval_cand` hot
                           path of the recsys serving stack.

Queries are processed in tiles so the [B, N] score matrix never fully
materializes for large N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.similarity import pair_scores


@functools.partial(jax.jit, static_argnames=("k",))
def _exact_topk_block(queries: jax.Array, items: jax.Array, k: int):
    scores = pair_scores(queries, items)
    vals, idxs = jax.lax.top_k(scores, k)
    return vals, idxs.astype(jnp.int32)


def exact_topk(
    queries: jax.Array,
    items: jax.Array,
    k: int = 10,
    query_tile: int = 1024,
    backend: str = "jnp",
):
    """[B, d] x [N, d] -> (scores [B, k], ids [B, k]) exact MIPS."""
    if backend == "pallas":
        from repro.kernels.mips_topk import ops as mips_ops

        return mips_ops.mips_topk(queries, items, k=k)
    b = queries.shape[0]
    vals_out, ids_out = [], []
    for s in range(0, b, query_tile):
        v, i = _exact_topk_block(queries[s : s + query_tile], items, k)
        vals_out.append(v)
        ids_out.append(i)
    return jnp.concatenate(vals_out), jnp.concatenate(ids_out)
