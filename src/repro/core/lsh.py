"""Simple-LSH (Neyshabur & Srebro 2015) — the LSH baseline of the paper's §5.

MIPS -> angular NNS reduction: items are scaled into the unit ball and
augmented with sqrt(1 - |x|^2); queries are normalized and augmented with 0.
Sign-random-projection codes then preserve the angle of the augmented pair.

We use the hamming-ranking variant (rank all items by code agreement, rerank
the top-T by exact inner product): it is the strongest form of the baseline
and maps to TPU-friendly matmuls — code agreement of {-1,+1} codes is a plain
[B, n_bits] x [N, n_bits] matmul.  Search effort is controlled by T
(= ``n_candidates``), so #similarity-evaluations is directly comparable with
the graph methods.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.similarity import gather_scores


class LSHResult(NamedTuple):
    ids: jax.Array      # [B, k]
    scores: jax.Array   # [B, k]
    evals: jax.Array    # [B] — exact rerank evaluations (=T)


@functools.partial(jax.jit, static_argnames=("k", "n_candidates"))
def _lsh_search(codes, planes, items, queries, *, k: int, n_candidates: int):
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-12
    )
    q_aug = jnp.concatenate([qn, jnp.zeros(qn.shape[:-1] + (1,), qn.dtype)], -1)
    q_codes = jnp.where(q_aug @ planes >= 0, 1.0, -1.0).astype(jnp.float32)
    agreement = jnp.einsum(
        "bh,nh->bn", q_codes, codes, preferred_element_type=jnp.float32
    )
    _, cand = jax.lax.top_k(agreement, n_candidates)
    exact = gather_scores(queries, items, cand.astype(jnp.int32))
    vals, sel = jax.lax.top_k(exact, k)
    ids = jnp.take_along_axis(cand, sel, axis=-1).astype(jnp.int32)
    b = queries.shape[0]
    return LSHResult(
        ids=ids,
        scores=vals,
        evals=jnp.full((b,), n_candidates, jnp.int32),
    )


@dataclass
class SimpleLSH:
    n_bits: int = 64
    seed: int = 0
    codes: Optional[jax.Array] = None
    planes: Optional[jax.Array] = None
    items: Optional[jax.Array] = None

    def build(self, items: jax.Array) -> "SimpleLSH":
        items = jnp.asarray(items)
        norms = jnp.linalg.norm(items, axis=-1, keepdims=True)
        scaled = items / jnp.max(norms)
        tail = jnp.sqrt(jnp.maximum(1.0 - jnp.sum(scaled * scaled, -1, keepdims=True), 0.0))
        aug = jnp.concatenate([scaled, tail], axis=-1)
        key = jax.random.PRNGKey(self.seed)
        planes = jax.random.normal(key, (aug.shape[-1], self.n_bits), jnp.float32)
        self.codes = jnp.where(aug @ planes >= 0, 1.0, -1.0).astype(jnp.float32)
        self.planes = planes
        self.items = items
        return self

    def search(self, queries: jax.Array, k: int = 10, n_candidates: int = 100):
        assert self.codes is not None, "call build() first"
        return _lsh_search(
            self.codes, self.planes, self.items, queries, k=k, n_candidates=n_candidates
        )
