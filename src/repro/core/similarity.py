"""Similarity functions for proximity-graph construction and search.

The paper uses two similarities:
  * inner product        s(x, y) = x . y                  (the MIPS objective)
  * angular similarity   s_a(x, y) = x . y / (|x| |y|)    (footnote 5: monotone
                                                           proxy for true angle)

Implementation note (TPU adaptation): angular search over a dataset is
identical to inner-product search over the *unit-normalized* dataset — for a
fixed query q, q.x/|x| is monotone in q.x_hat.  We therefore keep ONE batched
search engine (inner product) and materialize a normalized copy of the items
for the angular graph.  This keeps every hot loop a plain matmul/gather-dot.
"""
from __future__ import annotations

import enum

import jax
import jax.numpy as jnp


class Similarity(enum.Enum):
    INNER_PRODUCT = "ip"
    ANGULAR = "angular"
    NEG_L2 = "neg_l2"


def normalize(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Unit-normalize rows of ``x``."""
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def prepare_items(items: jax.Array, sim: Similarity) -> jax.Array:
    """Pre-transform the item matrix so that batched inner product implements
    the requested similarity ranking."""
    if sim == Similarity.INNER_PRODUCT:
        return items
    if sim == Similarity.ANGULAR:
        return normalize(items)
    if sim == Similarity.NEG_L2:
        # -|x-q|^2 = 2 q.x - |x|^2 - |q|^2 ; augment items with -|x|^2/2 and
        # queries with a constant 1 column (done by prepare_queries).
        sq = jnp.sum(items * items, axis=-1, keepdims=True)
        return jnp.concatenate([items, -0.5 * sq], axis=-1)
    raise ValueError(sim)


def prepare_queries(queries: jax.Array, sim: Similarity) -> jax.Array:
    if sim in (Similarity.INNER_PRODUCT, Similarity.ANGULAR):
        return queries
    if sim == Similarity.NEG_L2:
        ones = jnp.ones(queries.shape[:-1] + (1,), queries.dtype)
        return jnp.concatenate([queries, ones], axis=-1)
    raise ValueError(sim)


def pair_scores(queries: jax.Array, items: jax.Array) -> jax.Array:
    """[B, d] x [N, d] -> [B, N] inner products (fp32 accumulation)."""
    return jnp.einsum(
        "bd,nd->bn", queries, items, preferred_element_type=jnp.float32
    )


def gather_scores(queries: jax.Array, items: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-query gathered inner products.

    queries: [B, d]; items: [N, d]; ids: [B, W] int32 (may contain -1 padding,
    scored against row 0 — caller masks).  Returns [B, W] fp32.
    """
    safe = jnp.maximum(ids, 0)
    vecs = items[safe]  # [B, W, d]
    return jnp.einsum(
        "bd,bwd->bw", queries, vecs, preferred_element_type=jnp.float32
    )
