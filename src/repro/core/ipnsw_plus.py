"""ip-NSW+ (the paper's contribution, §4, Algorithm 3).

Two proximity graphs over the same items:
  A_s — angular NSW (similarity x.y/|x||y|; small M, l — paper uses 10/10)
  G_s — inner-product NSW (same parameters as plain ip-NSW)

Query processing (Algorithm 3):
  1. walk A_s to find the top-k' *angular* neighbors of q;
  2. seed the candidate pool with the G_s-neighbors of those angular
     neighbors ("the MIPS neighbor of an angular neighbor is likely an MIPS
     neighbor", Theorem 2);
  3. refine with a standard walk on G_s.

Construction (§4.2): items are inserted (mini-batched here, see build.py) into
A_s first; their G_s neighbors are then found with the ip-NSW+ search itself
(seeded from the just-computed angular neighbors), which the paper reports
gives more accurate inner-product neighbors than plain Algorithm-1 insertion.

TPU adaptation: both walks are the batched lock-step beam search of
``search.py``; the angular graph stores unit-normalized items so both walks
use the same inner-product engine (similarity.py note).  Seeding (Alg 3 lines
3-5) is one adjacency-row gather — [B, k'] ids -> [B, k'*M_g] seed matrix.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.build import (
    BUILD_BACKENDS,
    COMMIT_BACKENDS,
    _bootstrap_neighbors,
    batch_schedule,
    commit_batch,
    find_neighbors,
    resolve_commit_tile,
)
from repro.core.graph import GraphIndex, empty_graph
from repro.core.search import SearchResult, beam_search
from repro.core.similarity import normalize
from repro.core.storage import ItemStore, make_store, validate_storage

NEG_INF = jnp.float32(-jnp.inf)


class PlusResult(NamedTuple):
    ids: jax.Array          # [B, k] final MIPS ids
    scores: jax.Array       # [B, k] inner products
    evals: jax.Array        # [B] total similarity evaluations (angular + ip)
    ang_evals: jax.Array    # [B]
    ip_evals: jax.Array     # [B]
    visited_ang: jax.Array  # [B, Va] ids scored on A_s (Fig-5 data)
    visited_ip: jax.Array   # [B, Vi] ids scored on G_s
    trace: "Optional[object]" = None  # obs.WalkTrace of the G_s refine walk
    #   (the stage the paper's norm-bias figures measure); None untraced


def _seed_from_angular(ip_adj: jax.Array, ang_ids: jax.Array) -> jax.Array:
    """Alg 3 lines 3-5: candidate seeds = G_s out-neighbors of the angular
    results.  ang_ids: [B, k'] (-1 padded) -> [B, k'*M] (-1 padded)."""
    safe = jnp.maximum(ang_ids, 0)
    rows = ip_adj[safe]                      # [B, k', M]
    rows = jnp.where(ang_ids[..., None] >= 0, rows, -1)
    b = ang_ids.shape[0]
    return rows.reshape(b, -1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "ef", "ang_ef", "k_angular", "max_steps", "ang_max_steps",
        "backend", "storage",
    ),
)
def _search_plus(
    ang_graph: GraphIndex,
    ip_graph: GraphIndex,
    queries: jax.Array,
    ang_store: Optional[ItemStore] = None,
    ip_store: Optional[ItemStore] = None,
    valid: Optional[jax.Array] = None,
    live: Optional[jax.Array] = None,
    trace=None,
    *,
    k: int,
    ef: int,
    ang_ef: int,
    k_angular: int,
    max_steps: int,
    ang_max_steps: int,
    backend: str = "reference",
    storage: str = "f32",
) -> PlusResult:
    b = queries.shape[0]
    init_a = jnp.broadcast_to(ang_graph.entry[None, None], (b, 1)).astype(jnp.int32)
    # Angular ranking for a fixed query is monotone in q . x_hat, so the raw
    # query works against the normalized angular items (similarity.py).
    # With storage="int8" BOTH walks stream quantized stores (each graph has
    # its own — the angular one is over the normalized copy); each walk ends
    # with its own exact fp32 rerank, which for the angular stage merely
    # re-orders the seed neighborhood and for the ip stage is the final
    # asymmetric refine (DESIGN.md §8).
    # Both graphs index the SAME catalog slots, so one live mask serves both
    # walks (core/mutation.py tombstones a slot in A_s and G_s atomically).
    # The angular stage must also filter dead ids from ITS results: a dead
    # angular neighbor still routes the A_s walk, but its G_s out-edges are
    # stale precisely when it was deleted, so seeding from it would feed the
    # refine stage dead-leaning seeds.  (Seed rows themselves are -1-masked
    # through _seed_from_angular when the angular id is -1.)
    ang = beam_search(
        ang_graph,
        queries,
        init_a,
        pool_size=max(ang_ef, k_angular),
        max_steps=ang_max_steps,
        k=k_angular,
        backend=backend,
        storage=storage,
        store=ang_store,
        valid=valid,
        live=live,
    )
    seeds = _seed_from_angular(ip_graph.adj, ang.ids)
    # Tracing covers the G_s refine walk only: that is the walk whose norm
    # bias the paper measures (the angular stage walks the normalized copy,
    # where norm bands are degenerate by construction).
    ip = beam_search(
        ip_graph,
        queries,
        seeds,
        pool_size=max(ef, k),
        max_steps=max_steps,
        k=k,
        backend=backend,
        storage=storage,
        store=ip_store,
        valid=valid,
        live=live,
        trace=trace,
    )
    return PlusResult(
        ids=ip.ids,
        scores=ip.scores,
        evals=ang.evals + ip.evals,
        ang_evals=ang.evals,
        ip_evals=ip.evals,
        visited_ang=ang.visited,
        visited_ip=ip.visited,
        trace=ip.trace,
    )


@dataclass
class IpNSWPlus:
    """Dual-graph MIPS index (paper Algorithm 3 + §4.2 joint construction).

    Defaults mirror the paper: the angular graph uses M=10, l=10 without
    dataset-specific tuning; the inner-product graph uses the same parameters
    as plain ip-NSW.
    """

    max_degree: int = 16          # M for G_s
    ef_construction: int = 64     # l for G_s insertion
    ang_degree: int = 10          # M for A_s (paper: 10)
    ang_ef: int = 10              # l for A_s (paper: 10)
    k_angular: int = 10           # k' — angular results whose G_s edges seed C
    insert_batch: int = 128
    reverse_links: bool = True
    backend: str = "reference"    # walk step backend (search.STEP_BACKENDS)
    build_backend: str = "host"   # insertion driver (build.BUILD_BACKENDS)
    commit_backend: str = "reference"  # reverse-link merge (COMMIT_BACKENDS)
    commit_tile: Union[int, str] = "auto"  # fused-commit grid tiling (§7)
    storage: str = "f32"          # item store search streams (DESIGN.md §8)
    ang_graph: Optional[GraphIndex] = field(default=None)
    ip_graph: Optional[GraphIndex] = field(default=None)
    ang_store: Optional[ItemStore] = field(default=None)
    ip_store: Optional[ItemStore] = field(default=None)

    # ------------------------------------------------------------------ build

    def build(self, items: jax.Array, progress: bool = False) -> "IpNSWPlus":
        if self.build_backend not in BUILD_BACKENDS:
            raise ValueError(
                f"build_backend must be one of {BUILD_BACKENDS}, "
                f"got {self.build_backend!r}"
            )
        from repro.core.search import STEP_BACKENDS

        if self.backend not in STEP_BACKENDS:
            raise ValueError(
                f"backend must be one of {STEP_BACKENDS}, got {self.backend!r}"
            )
        if self.commit_backend not in COMMIT_BACKENDS:
            raise ValueError(
                f"commit_backend must be one of {COMMIT_BACKENDS}, "
                f"got {self.commit_backend!r}"
            )
        validate_storage(self.storage)
        items = jnp.asarray(items)
        n = items.shape[0]
        ang_items = normalize(items)
        norms = jnp.linalg.norm(items, axis=-1)
        ang_norms = jnp.ones((n,), jnp.float32)
        # One static tile for BOTH graphs' commits, resolved on host from the
        # raw item norms: the hub skew that makes targets collapse lives in
        # the ip graph; the angular graph shares the tile so the scan carry
        # stays a single static geometry.
        commit_tile = resolve_commit_tile(
            self.commit_tile,
            e=self.insert_batch * min(self.max_degree, self.ang_degree),
            norms=norms,
        )

        if self.build_backend == "scan":
            _, bids, valid = batch_schedule(n, self.insert_batch)
            arrays = _scan_build_plus_jit(
                items, ang_items, norms, ang_norms,
                jnp.asarray(bids), jnp.asarray(valid),
                max_degree=self.max_degree,
                ef_construction=self.ef_construction,
                ang_degree=self.ang_degree,
                ang_ef=self.ang_ef,
                k_angular=self.k_angular,
                insert_batch=self.insert_batch,
                reverse_links=self.reverse_links,
                backend=self.backend,
                commit_backend=self.commit_backend,
                commit_tile=commit_tile,
            )
            (a_adj, a_size, a_entry, a_enorm,
             i_adj, i_size, i_entry, i_enorm) = arrays
            self.ang_graph = GraphIndex(a_adj, ang_items, a_size, a_entry, a_enorm)
            self.ip_graph = GraphIndex(i_adj, items, i_size, i_entry, i_enorm)
            self._make_stores(self.storage)
            return self

        ang = empty_graph(ang_items, self.ang_degree)
        ip = empty_graph(items, self.max_degree)

        first = min(self.insert_batch, n)
        ids0 = jnp.arange(first, dtype=jnp.int32)
        a_nbr0, a_sc0 = _bootstrap_neighbors(ang_items[:first], self.ang_degree)
        ang = commit_batch(
            ang, ids0, a_nbr0, a_sc0, ang_norms,
            reverse_links=self.reverse_links,
            commit_backend=self.commit_backend,
            commit_tile=commit_tile,
        )
        g_nbr0, g_sc0 = _bootstrap_neighbors(items[:first], self.max_degree)
        ip = commit_batch(
            ip, ids0, g_nbr0, g_sc0, norms,
            reverse_links=self.reverse_links,
            commit_backend=self.commit_backend,
            commit_tile=commit_tile,
        )

        ang_steps = 2 * max(self.ang_ef, self.ang_degree)
        ip_steps = 2 * self.ef_construction

        start = first
        while start < n:
            stop = min(start + self.insert_batch, n)
            bids = jnp.arange(start, stop, dtype=jnp.int32)

            # 1. insert into the angular graph (plain Algorithm 2)
            a_nbr, a_sc = find_neighbors(
                ang,
                ang_items[start:stop],
                max_degree=self.ang_degree,
                ef=max(self.ang_ef, self.ang_degree),
                max_steps=ang_steps,
                backend=self.backend,
            )
            ang = commit_batch(
                ang, bids, a_nbr, a_sc, ang_norms,
                reverse_links=self.reverse_links,
                commit_backend=self.commit_backend,
                commit_tile=commit_tile,
            )

            # 2. insert into the ip graph with the ip-NSW+ search itself:
            #    seeds = G_s neighbors of the just-found angular neighbors.
            g_nbr, g_sc = _find_ip_neighbors_seeded(
                ip,
                items[start:stop],
                a_nbr[:, : self.k_angular],
                max_degree=self.max_degree,
                ef=self.ef_construction,
                max_steps=ip_steps,
                backend=self.backend,
            )
            ip = commit_batch(
                ip, bids, g_nbr, g_sc, norms,
                reverse_links=self.reverse_links,
                commit_backend=self.commit_backend,
                commit_tile=commit_tile,
            )

            if progress and (start // self.insert_batch) % 20 == 0:
                print(f"  inserted {stop}/{n}")
            start = stop

        self.ang_graph, self.ip_graph = ang, ip
        self._make_stores(self.storage)
        return self

    def _make_stores(self, storage: str) -> None:
        """Derive (and cache) both graphs' quantized stores post-build —
        one per graph, since the angular graph holds the normalized copy."""
        self.ang_store = make_store(self.ang_graph.items, storage)
        self.ip_store = make_store(self.ip_graph.items, storage)

    # ----------------------------------------------------------------- search

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        ef: int = 64,
        ang_ef: Optional[int] = None,
        k_angular: Optional[int] = None,
        max_steps: Optional[int] = None,
        backend: Optional[str] = None,
        storage: Optional[str] = None,
        valid: Optional[jax.Array] = None,
        live: Optional[jax.Array] = None,
        trace=None,
    ) -> PlusResult:
        """``valid`` is the [B] bucket-padding mask (search.beam_search),
        applied to BOTH walks: pad rows skip the angular stage, seed nothing,
        and return ids=-1 — the serving loop's fixed-shape entry point.
        ``live`` is the [N] tombstone mask (core/mutation.py), shared by both
        walks since the two graphs index the same catalog slots.  ``trace``
        is an optional obs.TraceContext over the RAW item norms; it traces
        the G_s refine walk (PlusResult.trace) at unchanged outputs."""
        assert self.ip_graph is not None, "call build() first"
        ang_ef = ang_ef if ang_ef is not None else self.ang_ef
        k_ang = k_angular if k_angular is not None else self.k_angular
        steps = max_steps if max_steps is not None else 2 * ef
        st = storage if storage is not None else self.storage
        validate_storage(st)
        if st == "int8" and self.ip_store is None:
            self._make_stores(st)  # f32-built index searched with int8
        ang_store = self.ang_store if st == "int8" else None
        ip_store = self.ip_store if st == "int8" else None
        return _search_plus(
            self.ang_graph,
            self.ip_graph,
            queries,
            ang_store,
            ip_store,
            valid,
            live,
            trace,
            k=k,
            ef=ef,
            ang_ef=ang_ef,
            k_angular=k_ang,
            max_steps=steps,
            ang_max_steps=2 * max(ang_ef, k_ang),
            backend=backend if backend is not None else self.backend,
            storage=st,
        )


@functools.partial(
    jax.jit, static_argnames=("max_degree", "ef", "max_steps", "backend")
)
def _find_ip_neighbors_seeded(
    ip_graph: GraphIndex,
    batch_items: jax.Array,
    ang_nbr_ids: jax.Array,
    live: Optional[jax.Array] = None,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
):
    """§4.2 insertion: find an item's G_s neighbors by the ip-NSW+ search
    (angular-seeded walk) instead of a cold entry-vertex walk.  ``live`` is
    the mutation layer's tombstone mask — upserts pass it so fresh content
    never links to a dead slot (build.find_neighbors has the same knob)."""
    seeds = _seed_from_angular(ip_graph.adj, ang_nbr_ids)
    # include the entry vertex so the very first batches (sparse adjacency)
    # still have a valid start.
    b = batch_items.shape[0]
    entry = jnp.broadcast_to(ip_graph.entry[None, None], (b, 1)).astype(jnp.int32)
    seeds = jnp.concatenate([seeds, entry], axis=-1)
    res = beam_search(
        ip_graph,
        batch_items,
        seeds,
        pool_size=ef,
        max_steps=max_steps,
        k=max_degree,
        backend=backend,
        live=live,
    )
    ids = jnp.where(res.scores > NEG_INF, res.ids, -1)
    return ids, res.scores


# ---------------------------------------------------------------------------
# Scan build backend (§4.2 construction as one lax.scan over both graphs)
# ---------------------------------------------------------------------------


def scan_build_plus_arrays(
    items: jax.Array,
    ang_items: jax.Array,
    norms: jax.Array,
    ang_norms: jax.Array,
    batch_ids: jax.Array,    # [T, B] int32 (tail clamped)
    batch_valid: jax.Array,  # [T, B] bool
    *,
    max_degree: int,
    ef_construction: int,
    ang_degree: int,
    ang_ef: int,
    k_angular: int,
    insert_batch: int,
    reverse_links: bool,
    backend: str,
    commit_backend: str = "reference",
    commit_tile: Union[int, str] = "auto",
):
    """Fully-traced ip-NSW+ build: bootstrap both graphs, then one
    ``lax.scan`` whose carry holds *both* adjacencies, so the §4.2
    interleaving (angular insert -> angular-seeded ip insert) survives
    intact with zero host round-trips.  Returns
    ``(ang_adj, ang_size, ang_entry, ang_entry_norm,
       ip_adj, ip_size, ip_entry, ip_entry_norm)``.
    ``build_sharded`` vmaps this over a leading shard axis.  ``commit_tile``
    must already be static — resolve "auto" on host before tracing to use
    the norm-skew heuristic (IpNSWPlus.build does)."""
    n = items.shape[0]
    ang = empty_graph(ang_items, ang_degree)
    ip = empty_graph(items, max_degree)

    first = min(insert_batch, n)
    ids0 = jnp.arange(first, dtype=jnp.int32)
    a_nbr0, a_sc0 = _bootstrap_neighbors(ang_items[:first], ang_degree)
    ang = commit_batch(
        ang, ids0, a_nbr0, a_sc0, ang_norms, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )
    g_nbr0, g_sc0 = _bootstrap_neighbors(items[:first], max_degree)
    ip = commit_batch(
        ip, ids0, g_nbr0, g_sc0, norms, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )

    ang_steps = 2 * max(ang_ef, ang_degree)
    ip_steps = 2 * ef_construction

    def body(carry, xs):
        (a_adj, a_size, a_entry, a_enorm,
         i_adj, i_size, i_entry, i_enorm) = carry
        bids, vmask = xs
        ang_g = GraphIndex(a_adj, ang_items, a_size, a_entry, a_enorm)
        ip_g = GraphIndex(i_adj, items, i_size, i_entry, i_enorm)

        # 1. insert into the angular graph (plain Algorithm 2)
        a_nbr, a_sc = find_neighbors(
            ang_g,
            jnp.take(ang_items, bids, axis=0),
            max_degree=ang_degree,
            ef=max(ang_ef, ang_degree),
            max_steps=ang_steps,
            backend=backend,
        )
        ang2 = commit_batch(
            ang_g, bids,
            jnp.where(vmask[:, None], a_nbr, -1),
            jnp.where(vmask[:, None], a_sc, NEG_INF),
            ang_norms, valid=vmask, reverse_links=reverse_links,
            commit_backend=commit_backend, commit_tile=commit_tile,
        )

        # 2. insert into the ip graph with the ip-NSW+ search itself,
        #    seeded from the just-found (unmasked — valid rows only matter)
        #    angular neighbors, against the pre-commit ip graph.
        g_nbr, g_sc = _find_ip_neighbors_seeded(
            ip_g,
            jnp.take(items, bids, axis=0),
            a_nbr[:, :k_angular],
            max_degree=max_degree,
            ef=ef_construction,
            max_steps=ip_steps,
            backend=backend,
        )
        ip2 = commit_batch(
            ip_g, bids,
            jnp.where(vmask[:, None], g_nbr, -1),
            jnp.where(vmask[:, None], g_sc, NEG_INF),
            norms, valid=vmask, reverse_links=reverse_links,
            commit_backend=commit_backend, commit_tile=commit_tile,
        )
        return (ang2.adj, ang2.size, ang2.entry, ang2.entry_norm,
                ip2.adj, ip2.size, ip2.entry, ip2.entry_norm), None

    carry = (ang.adj, ang.size, ang.entry, ang.entry_norm,
             ip.adj, ip.size, ip.entry, ip.entry_norm)
    if batch_ids.shape[0]:
        carry, _ = jax.lax.scan(body, carry, (batch_ids, batch_valid))
    return carry


# Single-index entry point.  Both adjacencies live only as scan carries
# inside the trace, so XLA aliases them in place across iterations.
_scan_build_plus_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "max_degree", "ef_construction", "ang_degree", "ang_ef", "k_angular",
        "insert_batch", "reverse_links", "backend", "commit_backend",
        "commit_tile",
    ),
)(scan_build_plus_arrays)
