"""Streaming index mutation — live upsert / tombstone delete / relink repair.

The paper's graphs are built once and served forever; production catalogs
churn.  The norm bias the paper identifies (§3–4) makes churn *dangerous*
rather than merely inconvenient: walks funnel through a small set of
large-norm, high-in-degree hubs, so deleting a few of them can sever
navigability far out of proportion to the fraction of items removed.  This
module is the robustness layer that absorbs interleaved upserts, deletes and
adversarial hub failures with bounded, measurable degradation (DESIGN.md §9):

  tombstones  — a delete flips one bit of a ``[N] bool`` live mask.  Dead
                nodes KEEP their vectors and adjacency: walks traverse
                through them (they remain the routing highways), but every
                search path filters them from results
                (``search.beam_search(live=)``) so they are never returned.
  free slots  — a fixed-capacity slot pool.  Upserts reuse tombstoned slots
                (FIFO by deletion time) before touching never-used headroom,
                so steady-state churn holds the graph's high-water mark flat
                and every mutation is an in-place row update under jit with
                donated carries — no reallocation, no recompilation.
  relink      — the incremental repair pass.  A live node whose out-edges
                point mostly at tombstones is a routing dead-end in the
                making; ``relink(budget)`` re-runs the Algorithm-2 neighbor
                search (live-masked) + commit for the worst offenders,
                paying down "relink debt" a budget-slice at a time so repair
                work interleaves with serving instead of stopping the world.

``MutableIndex`` wraps a built ``IpNSW`` or ``IpNSWPlus`` (both graphs of the
latter mutate atomically — the two index the same catalog slots, so one live
mask serves both).  ``ChurnTrace`` generates the seeded churn/fault-injection
event streams (upserts, deletes, hub kills, relinks) that
``launch/serve_loop.ServeLoop.run(churn=)`` replays against query traffic,
and ``core/invariants.py`` is the safety net checked in tests and opt-in at
runtime.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.build import NEG_INF, commit_batch, find_neighbors
from repro.core.graph import GraphIndex, in_degrees
from repro.core.invariants import check_graph_invariants, dead_edge_fraction
from repro.core.ipnsw import IpNSW
from repro.core.ipnsw_plus import IpNSWPlus, _find_ip_neighbors_seeded
from repro.core.similarity import normalize
from repro.core.storage import ItemStore, quantize_items, update_store_rows
from repro.kernels.commit_merge import resolve_commit_tile


# ---------------------------------------------------------------------------
# jitted mutation bodies (fixed shapes; adjacency/items/norms/live donated)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("max_degree", "ef", "max_steps", "backend",
                     "commit_backend", "commit_tile", "reverse_links"),
    donate_argnums=(0, 1, 2, 3),
)
def _upsert_arrays(
    adj, items, norms, live, size, entry, entry_norm,
    slots, new_items, valid, *,
    max_degree, ef, max_steps, backend,
    commit_backend, commit_tile, reverse_links,
):
    """One padded upsert batch against a plain ip-NSW graph.

    Batch slots are dead for the duration of the neighbor search (fresh
    slots were never live; reused slots are tombstones), so the live-masked
    walk can neither link a new item to a batch member's half-written row
    nor to itself; they flip live only after the commit lands."""
    n = adj.shape[0]
    rows = jnp.where(valid, slots, n)          # pad rows drop out of range
    items = items.at[rows].set(new_items, mode="drop")
    norms = norms.at[rows].set(
        jnp.linalg.norm(new_items, axis=-1), mode="drop"
    )
    live = live.at[rows].set(False, mode="drop")
    graph = GraphIndex(adj=adj, items=items, size=size, entry=entry,
                       entry_norm=entry_norm)
    nbr, sc = find_neighbors(
        graph, new_items, live, max_degree=max_degree, ef=ef,
        max_steps=max_steps, backend=backend,
    )
    nbr = jnp.where(valid[:, None], nbr, -1)
    sc = jnp.where(valid[:, None], sc, NEG_INF)
    g = commit_batch(
        graph, slots, nbr, sc, norms, valid=valid,
        reverse_links=reverse_links, commit_backend=commit_backend,
        commit_tile=commit_tile,
    )
    live = live.at[rows].set(True, mode="drop")
    return g.adj, g.size, g.entry, g.entry_norm, items, norms, live


@functools.partial(
    jax.jit,
    static_argnames=("max_degree", "ef", "max_steps", "ang_degree", "ang_ef",
                     "ang_max_steps", "k_angular", "backend",
                     "commit_backend", "commit_tile", "reverse_links"),
    donate_argnums=(0, 1, 2, 3, 4, 5),
)
def _upsert_plus_arrays(
    a_adj, i_adj, items, ang_items, norms, live,
    a_size, a_entry, a_enorm, i_size, i_entry, i_enorm,
    slots, new_items, valid, *,
    max_degree, ef, max_steps, ang_degree, ang_ef, ang_max_steps, k_angular,
    backend, commit_backend, commit_tile, reverse_links,
):
    """One padded upsert batch against BOTH ip-NSW+ graphs (§4.2 order:
    angular insert first, then the angular-seeded ip insert)."""
    n = i_adj.shape[0]
    new_ang = normalize(new_items)
    rows = jnp.where(valid, slots, n)
    items = items.at[rows].set(new_items, mode="drop")
    ang_items = ang_items.at[rows].set(new_ang, mode="drop")
    norms = norms.at[rows].set(
        jnp.linalg.norm(new_items, axis=-1), mode="drop"
    )
    live = live.at[rows].set(False, mode="drop")
    ang_norms = jnp.ones_like(norms)

    ang_g = GraphIndex(adj=a_adj, items=ang_items, size=a_size,
                       entry=a_entry, entry_norm=a_enorm)
    ip_g = GraphIndex(adj=i_adj, items=items, size=i_size,
                      entry=i_entry, entry_norm=i_enorm)

    a_nbr, a_sc = find_neighbors(
        ang_g, new_ang, live, max_degree=ang_degree,
        ef=max(ang_ef, ang_degree), max_steps=ang_max_steps, backend=backend,
    )
    ang2 = commit_batch(
        ang_g, slots,
        jnp.where(valid[:, None], a_nbr, -1),
        jnp.where(valid[:, None], a_sc, NEG_INF),
        ang_norms, valid=valid, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )

    g_nbr, g_sc = _find_ip_neighbors_seeded(
        ip_g, new_items, a_nbr[:, :k_angular], live,
        max_degree=max_degree, ef=ef, max_steps=max_steps, backend=backend,
    )
    ip2 = commit_batch(
        ip_g, slots,
        jnp.where(valid[:, None], g_nbr, -1),
        jnp.where(valid[:, None], g_sc, NEG_INF),
        norms, valid=valid, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )
    live = live.at[rows].set(True, mode="drop")
    return (ang2.adj, ang2.size, ang2.entry, ang2.entry_norm,
            ip2.adj, ip2.size, ip2.entry, ip2.entry_norm,
            items, ang_items, norms, live)


@functools.partial(jax.jit, donate_argnums=(0,))
def _delete_arrays(live, norms, entry, entry_norm, ids, valid):
    """Flip tombstone bits and re-seat the entry vertex if it died.

    The replacement entry is the max-norm LIVE node — the same criterion the
    build maintains incrementally — recomputed here with one full masked
    argmax, which is fine on the rare delete-hit-the-entry path."""
    n = live.shape[0]
    live = live.at[jnp.where(valid, ids, n)].set(False, mode="drop")
    masked = jnp.where(live, norms, NEG_INF)
    new_entry = jnp.argmax(masked).astype(jnp.int32)
    need = ~live[entry]
    entry = jnp.where(need, new_entry, entry).astype(jnp.int32)
    entry_norm = jnp.where(need, masked[new_entry],
                           entry_norm).astype(jnp.float32)
    return live, entry, entry_norm, need


@functools.partial(
    jax.jit,
    static_argnames=("max_degree", "ef", "max_steps", "backend",
                     "commit_backend", "commit_tile", "reverse_links"),
    donate_argnums=(0,),
)
def _relink_arrays(
    adj, items, norms, live, size, entry, entry_norm, slots, valid, *,
    max_degree, ef, max_steps, backend,
    commit_backend, commit_tile, reverse_links,
):
    """Re-run find+commit for a batch of live nodes whose out-edges rotted.

    Unlike an upsert the node itself is live during the search (it must stay
    servable), so its own id can come back as its best neighbor — masked to
    -1 before the commit (invariant I3)."""
    graph = GraphIndex(adj=adj, items=items, size=size, entry=entry,
                       entry_norm=entry_norm)
    b_items = jnp.take(items, slots, axis=0)
    nbr, sc = find_neighbors(
        graph, b_items, live, max_degree=max_degree, ef=ef,
        max_steps=max_steps, backend=backend,
    )
    self_hit = nbr == slots[:, None]
    nbr = jnp.where(self_hit | ~valid[:, None], -1, nbr)
    sc = jnp.where(self_hit | ~valid[:, None], NEG_INF, sc)
    g = commit_batch(
        graph, slots, nbr, sc, norms, valid=valid,
        reverse_links=reverse_links, commit_backend=commit_backend,
        commit_tile=commit_tile,
    )
    return g.adj, g.size, g.entry, g.entry_norm


@functools.partial(
    jax.jit,
    static_argnames=("max_degree", "ef", "max_steps", "ang_degree", "ang_ef",
                     "ang_max_steps", "k_angular", "backend",
                     "commit_backend", "commit_tile", "reverse_links"),
    donate_argnums=(0, 1),
)
def _relink_plus_arrays(
    a_adj, i_adj, items, ang_items, norms, live,
    a_size, a_entry, a_enorm, i_size, i_entry, i_enorm,
    slots, valid, *,
    max_degree, ef, max_steps, ang_degree, ang_ef, ang_max_steps, k_angular,
    backend, commit_backend, commit_tile, reverse_links,
):
    ang_norms = jnp.ones_like(norms)
    ang_g = GraphIndex(adj=a_adj, items=ang_items, size=a_size,
                       entry=a_entry, entry_norm=a_enorm)
    ip_g = GraphIndex(adj=i_adj, items=items, size=i_size,
                      entry=i_entry, entry_norm=i_enorm)

    a_nbr, a_sc = find_neighbors(
        ang_g, jnp.take(ang_items, slots, axis=0), live,
        max_degree=ang_degree, ef=max(ang_ef, ang_degree),
        max_steps=ang_max_steps, backend=backend,
    )
    a_self = a_nbr == slots[:, None]
    ang2 = commit_batch(
        ang_g, slots,
        jnp.where(a_self | ~valid[:, None], -1, a_nbr),
        jnp.where(a_self | ~valid[:, None], NEG_INF, a_sc),
        ang_norms, valid=valid, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )

    g_nbr, g_sc = _find_ip_neighbors_seeded(
        ip_g, jnp.take(items, slots, axis=0), a_nbr[:, :k_angular], live,
        max_degree=max_degree, ef=ef, max_steps=max_steps, backend=backend,
    )
    g_self = g_nbr == slots[:, None]
    ip2 = commit_batch(
        ip_g, slots,
        jnp.where(g_self | ~valid[:, None], -1, g_nbr),
        jnp.where(g_self | ~valid[:, None], NEG_INF, g_sc),
        norms, valid=valid, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )
    return (ang2.adj, ang2.size, ang2.entry, ang2.entry_norm,
            ip2.adj, ip2.size, ip2.entry, ip2.entry_norm)


# ---------------------------------------------------------------------------
# MutableIndex
# ---------------------------------------------------------------------------


def _pad_graph(g: GraphIndex, capacity: int) -> GraphIndex:
    n, _ = g.adj.shape
    if capacity == n:
        return g
    pad = capacity - n
    return GraphIndex(
        adj=jnp.pad(g.adj, ((0, pad), (0, 0)), constant_values=-1),
        items=jnp.pad(g.items, ((0, pad), (0, 0))),
        size=g.size, entry=g.entry, entry_norm=g.entry_norm,
    )


class MutableIndex:
    """A built ``IpNSW``/``IpNSWPlus`` opened for streaming mutation.

    Construction pads the graph arrays once to ``capacity`` rows (never-used
    tail: adj -1, items 0, live False); every subsequent mutation is a
    fixed-shape jitted update with donated carries, so steady-state churn
    triggers zero recompiles and zero reallocations.  Mutations are applied
    in padded batches of ``mutation_batch`` — the one compiled program per
    (op, shape) pair that makes the jit cache stable.

    Slot policy (deterministic): tombstoned slots are reused FIFO by
    deletion time, then never-used headroom in ascending order.  When both
    are exhausted, ``upsert`` raises RuntimeError BEFORE touching any device
    state — graceful refusal, never corruption (tests/test_mutation.py).

    The wrapped index object stays the single source of truth for search:
    every mutation writes the updated graphs (and int8 store rows) back into
    it, and ``search()`` delegates with ``live=`` attached.  Consistency is
    per-batch: a search issued between two mutation batches sees the fully
    committed prefix, nothing half-written.
    """

    def __init__(
        self,
        index: Union[IpNSW, IpNSWPlus],
        *,
        capacity: Optional[int] = None,
        mutation_batch: int = 32,
        relink_threshold: float = 0.3,
    ):
        if not isinstance(index, (IpNSW, IpNSWPlus)):
            raise TypeError(
                f"MutableIndex wraps IpNSW or IpNSWPlus, got {type(index)}"
            )
        self.index = index
        self.plus = isinstance(index, IpNSWPlus)
        g = index.ip_graph if self.plus else index.graph
        if g is None:
            raise ValueError("index must be built before mutation")
        n0 = g.capacity
        self.capacity = n0 if capacity is None else int(capacity)
        if self.capacity < n0:
            raise ValueError(
                f"capacity {self.capacity} below built size {n0}"
            )
        if mutation_batch <= 0:
            raise ValueError(f"mutation_batch must be positive, got "
                             f"{mutation_batch}")
        self.mutation_batch = int(mutation_batch)
        self.relink_threshold = float(relink_threshold)

        if self.plus:
            index.ip_graph = _pad_graph(index.ip_graph, self.capacity)
            index.ang_graph = _pad_graph(index.ang_graph, self.capacity)
            g = index.ip_graph
        else:
            index.graph = _pad_graph(index.graph, self.capacity)
            g = index.graph
        self._pad_stores()

        size0 = int(g.size)
        self.norms = jnp.linalg.norm(g.items, axis=-1)
        self.live = (jnp.arange(self.capacity) < size0)
        self._live_host = np.asarray(self.live).copy()
        self._next_fresh = size0
        self._free: deque = deque()   # tombstones, FIFO by deletion time
        self.mutation_count = 0

        # Static commit tile resolved once, on host, from the live norms —
        # the same norm-skew heuristic the build drivers use.
        self._commit_tile = resolve_commit_tile(
            index.commit_tile,
            e=self.mutation_batch * index.max_degree,
            norms=np.asarray(self.norms)[:size0],
        )

    # -- introspection -----------------------------------------------------

    @property
    def graph(self) -> GraphIndex:
        """The (ip) graph currently served."""
        return self.index.ip_graph if self.plus else self.index.graph

    @property
    def size(self) -> int:
        """High-water mark of used slots (tombstones included)."""
        return int(self.graph.size)

    def free_slots(self) -> int:
        return len(self._free) + (self.capacity - self._next_fresh)

    def live_ids(self) -> np.ndarray:
        return np.flatnonzero(self._live_host)

    # -- store / graph write-back helpers ----------------------------------

    def _pad_stores(self) -> None:
        idx = self.index
        def pad(store: Optional[ItemStore], items) -> Optional[ItemStore]:
            if store is None:
                return None
            n = store.scales.shape[0]
            if n == self.capacity:
                return store
            # Re-derive from the padded items: pad rows are zero vectors,
            # which quantize to zero codes / epsilon scales (score 0.0).
            return quantize_items(items)
        if self.plus:
            idx.ip_store = pad(idx.ip_store, idx.ip_graph.items)
            idx.ang_store = pad(idx.ang_store, idx.ang_graph.items)
        else:
            idx.store = pad(idx.store, idx.graph.items)

    def _sync_store_rows(self, slots: jax.Array, new_items: jax.Array,
                         new_ang: Optional[jax.Array], valid) -> None:
        """Mirror an upsert's item rows into the cached int8 stores (rows of
        pad slots are dropped the same way the array updates drop them)."""
        idx = self.index
        n = self.capacity
        rows = jnp.where(jnp.asarray(valid), jnp.asarray(slots), n)
        if self.plus:
            if idx.ip_store is not None:
                idx.ip_store = update_store_rows(idx.ip_store, rows, new_items)
            if idx.ang_store is not None:
                idx.ang_store = update_store_rows(idx.ang_store, rows, new_ang)
        elif idx.store is not None:
            idx.store = update_store_rows(idx.store, rows, new_items)

    # -- allocation --------------------------------------------------------

    def _allocate(self, b: int) -> np.ndarray:
        if b > self.free_slots():
            raise RuntimeError(
                f"free-slot pool exhausted: need {b} slots, have "
                f"{self.free_slots()} (capacity {self.capacity}, "
                f"high-water {self._next_fresh}, tombstones "
                f"{len(self._free)}) — grow capacity= or delete first"
            )
        out: List[int] = []
        while len(out) < b and self._free:
            out.append(self._free.popleft())
        while len(out) < b:
            out.append(self._next_fresh)
            self._next_fresh += 1
        return np.asarray(out, np.int32)

    def _chunks(self, ids: np.ndarray, payload: Optional[np.ndarray] = None):
        """Yield (slots[mb], payload[mb, d]|None, valid[mb]) padded chunks."""
        mb = self.mutation_batch
        d = self.graph.items.shape[1]
        for i in range(0, len(ids), mb):
            part = ids[i:i + mb]
            slots = np.zeros(mb, np.int32)
            slots[:len(part)] = part
            valid = np.zeros(mb, bool)
            valid[:len(part)] = True
            if payload is None:
                yield jnp.asarray(slots), None, jnp.asarray(valid)
            else:
                pay = np.zeros((mb, d), np.float32)
                pay[:len(part)] = payload[i:i + mb]
                yield jnp.asarray(slots), jnp.asarray(pay), jnp.asarray(valid)

    # -- mutations ---------------------------------------------------------

    def upsert(self, new_items) -> np.ndarray:
        """Insert (or replace, via slot reuse) a batch of items; returns the
        slot ids assigned, in payload order."""
        new_items = np.asarray(new_items, np.float32)
        if new_items.ndim != 2 or new_items.shape[1] != self.graph.items.shape[1]:
            raise ValueError(
                f"upsert payload must be [b, {self.graph.items.shape[1]}], "
                f"got {new_items.shape}"
            )
        slots = self._allocate(new_items.shape[0])
        idx = self.index
        knobs = dict(
            max_degree=idx.max_degree,
            ef=idx.ef_construction,
            max_steps=2 * idx.ef_construction,
            backend=idx.backend,
            commit_backend=idx.commit_backend,
            commit_tile=self._commit_tile,
            reverse_links=idx.reverse_links,
        )
        for cslots, pay, valid in self._chunks(slots, new_items):
            if self.plus:
                ag, ig = idx.ang_graph, idx.ip_graph
                (a_adj, a_size, a_entry, a_enorm,
                 i_adj, i_size, i_entry, i_enorm,
                 items, ang_items, self.norms, self.live) = _upsert_plus_arrays(
                    ag.adj, ig.adj, ig.items, ag.items, self.norms, self.live,
                    ag.size, ag.entry, ag.entry_norm,
                    ig.size, ig.entry, ig.entry_norm,
                    cslots, pay, valid,
                    ang_degree=idx.ang_degree, ang_ef=idx.ang_ef,
                    ang_max_steps=2 * max(idx.ang_ef, idx.ang_degree),
                    k_angular=idx.k_angular, **knobs,
                )
                idx.ang_graph = GraphIndex(a_adj, ang_items, a_size,
                                           a_entry, a_enorm)
                idx.ip_graph = GraphIndex(i_adj, items, i_size,
                                          i_entry, i_enorm)
                self._sync_store_rows(cslots, pay, normalize(pay), valid)
            else:
                g = idx.graph
                (adj, size, entry, enorm,
                 items, self.norms, self.live) = _upsert_arrays(
                    g.adj, g.items, self.norms, self.live,
                    g.size, g.entry, g.entry_norm,
                    cslots, pay, valid, **knobs,
                )
                idx.graph = GraphIndex(adj, items, size, entry, enorm)
                self._sync_store_rows(cslots, pay, None, valid)
        self._live_host[slots] = True
        self.mutation_count += 1
        return slots

    def delete(self, ids) -> None:
        """Tombstone a batch of live slots.  The rows stay in the graph as
        routing vertices; searches stop returning them immediately."""
        ids = np.unique(np.asarray(ids, np.int32).ravel())
        if ids.size == 0:
            return
        if ids.min() < 0 or ids.max() >= self._next_fresh:
            raise ValueError(
                f"delete ids must be used slots in [0, {self._next_fresh}), "
                f"got range [{ids.min()}, {ids.max()}]"
            )
        dead = ids[~self._live_host[ids]]
        if dead.size:
            raise ValueError(f"slots already tombstoned: {dead.tolist()}")
        if int(self._live_host.sum()) - ids.size < 1:
            raise RuntimeError("delete would tombstone the entire catalog")
        for cids, _, valid in self._chunks(ids):
            ip = self.graph
            self.live, entry, enorm, moved = _delete_arrays(
                self.live, self.norms, ip.entry, ip.entry_norm, cids, valid,
            )
            if self.plus:
                self.index.ip_graph = ip._replace(entry=entry,
                                                  entry_norm=enorm)
                if bool(moved):
                    # The angular entry only needs to be SOME live vertex;
                    # reuse the ip re-seat (all angular norms are 1.0).
                    self.index.ang_graph = self.index.ang_graph._replace(
                        entry=entry,
                        entry_norm=jnp.ones((), jnp.float32),
                    )
            else:
                self.index.graph = ip._replace(entry=entry, entry_norm=enorm)
        self._live_host[ids] = False
        self._free.extend(ids.tolist())
        self.mutation_count += 1

    def kill_hubs(self, k: int) -> np.ndarray:
        """Adversarial fault injection: tombstone the k live nodes with the
        highest in-degree — the §4 hubs whose loss hurts navigability most.
        Never kills the last live node; returns the ids killed."""
        indeg = in_degrees(self.graph)
        indeg = np.where(self._live_host[:len(indeg)], indeg, -1)
        k = min(int(k), max(int(self._live_host.sum()) - 1, 0))
        if k <= 0:
            return np.asarray([], np.int32)
        order = np.lexsort((np.arange(len(indeg)), -indeg))  # ties -> low id
        ids = np.asarray(order[:k], np.int32)
        self.delete(ids)
        return ids

    # -- repair ------------------------------------------------------------

    def _relink_candidates(self) -> np.ndarray:
        """Live used rows ordered worst-first by dead-out-edge fraction
        (ties by id), cut at ``relink_threshold``."""
        size = self.size
        adj = np.asarray(self.graph.adj)[:size]
        live = self._live_host
        edge = (adj >= 0) & live[:size, None]
        n_edges = edge.sum(axis=1)
        dead = (edge & ~live[np.maximum(adj, 0)]).sum(axis=1)
        frac = np.where(n_edges > 0, dead / np.maximum(n_edges, 1), 0.0)
        cand = np.flatnonzero(frac >= self.relink_threshold)
        return cand[np.lexsort((cand, -frac[cand]))].astype(np.int32)

    def relink_debt(self) -> int:
        """Nodes currently above the repair threshold."""
        return int(len(self._relink_candidates()))

    def relink(self, budget: int) -> int:
        """Repair up to ``budget`` of the worst rotted live nodes; returns
        how many were relinked.  Call repeatedly (or with a large budget)
        until ``relink_debt() == 0`` for a full repair."""
        todo = self._relink_candidates()[:max(int(budget), 0)]
        if todo.size == 0:
            return 0
        idx = self.index
        knobs = dict(
            max_degree=idx.max_degree,
            ef=idx.ef_construction,
            max_steps=2 * idx.ef_construction,
            backend=idx.backend,
            commit_backend=idx.commit_backend,
            commit_tile=self._commit_tile,
            reverse_links=idx.reverse_links,
        )
        for cslots, _, valid in self._chunks(todo):
            if self.plus:
                ag, ig = idx.ang_graph, idx.ip_graph
                (a_adj, a_size, a_entry, a_enorm,
                 i_adj, i_size, i_entry, i_enorm) = _relink_plus_arrays(
                    ag.adj, ig.adj, ig.items, ag.items, self.norms, self.live,
                    ag.size, ag.entry, ag.entry_norm,
                    ig.size, ig.entry, ig.entry_norm,
                    cslots, valid,
                    ang_degree=idx.ang_degree, ang_ef=idx.ang_ef,
                    ang_max_steps=2 * max(idx.ang_ef, idx.ang_degree),
                    k_angular=idx.k_angular, **knobs,
                )
                idx.ang_graph = GraphIndex(a_adj, ag.items, a_size,
                                           a_entry, a_enorm)
                idx.ip_graph = GraphIndex(i_adj, ig.items, i_size,
                                          i_entry, i_enorm)
            else:
                g = idx.graph
                adj, size, entry, enorm = _relink_arrays(
                    g.adj, g.items, self.norms, self.live,
                    g.size, g.entry, g.entry_norm, cslots, valid, **knobs,
                )
                idx.graph = GraphIndex(adj, g.items, size, entry, enorm)
        self.mutation_count += 1
        return int(todo.size)

    # -- observability -----------------------------------------------------

    def health(self) -> Dict[str, float]:
        """Churn-health counters (surfaced in ServeStats during serving)."""
        size = max(self.size, 1)
        live_n = int(self._live_host.sum())
        fracs = [dead_edge_fraction(np.asarray(self.graph.adj),
                                    self._live_host, self.size)]
        if self.plus:
            fracs.append(dead_edge_fraction(
                np.asarray(self.index.ang_graph.adj),
                self._live_host, self.size))
        return {
            "live_fraction": live_n / size,
            "tombstone_ratio": 1.0 - live_n / size,
            "dead_edge_frac": float(max(fracs)),
            "relink_debt": float(self.relink_debt()),
            # Upsert capacity remaining (free tombstone slots + never-used
            # headroom as a fraction of capacity): 0.0 means the next batch
            # upsert without a matching delete raises.
            "pool_headroom": self.free_slots() / max(self.capacity, 1),
        }

    def check_invariants(self, max_dead_edge_frac: float = 1.0) -> List[str]:
        """Run core/invariants.py over every graph; returns violations."""
        errs = check_graph_invariants(
            self.graph, self._live_host,
            max_dead_edge_frac=max_dead_edge_frac,
            name="ip" if self.plus else "graph",
        )
        if self.plus:
            errs += check_graph_invariants(
                self.index.ang_graph, self._live_host,
                max_dead_edge_frac=max_dead_edge_frac, name="ang",
            )
        return errs

    # -- search ------------------------------------------------------------

    def search(self, queries, **kwargs):
        """Delegate to the wrapped index with the tombstone mask attached."""
        return self.index.search(queries, live=self.live, **kwargs)


# ---------------------------------------------------------------------------
# Churn / fault-injection traces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChurnEvent:
    """One timed mutation.  ``kind``:
      "upsert"   — insert ``items`` ([b, d] payload baked into the trace)
      "delete"   — tombstone ``count`` uniformly-chosen live slots
                   (selection rng seeded with ``seed`` at APPLY time, so a
                   replay against the same state sequence is deterministic)
      "hub_kill" — tombstone the ``count`` highest-in-degree live nodes
      "relink"   — run a repair pass with budget ``count``
    """

    t: float
    kind: str
    items: Optional[np.ndarray] = None
    count: int = 0
    seed: int = 0


@dataclass(frozen=True)
class ChurnTrace:
    """A seeded, fully materialized churn schedule (pure function of its
    generation arguments — no wall clock, no global rng)."""

    events: Tuple[ChurnEvent, ...]

    @property
    def n_events(self) -> int:
        return len(self.events)

    @staticmethod
    def generate(
        *,
        n_items: int,
        dim: int,
        duration_s: float,
        turnover: float = 0.2,
        batch: int = 32,
        seed: int = 0,
        profile: str = "gaussian",
        hub_kill_at: Optional[float] = None,
        hub_kill_k: int = 0,
        relink_every: Optional[float] = None,
        relink_budget: int = 0,
        start_t: float = 0.0,
    ) -> "ChurnTrace":
        """``turnover`` is the catalog fraction both UPSERTED and DELETED
        over ``duration_s`` (0.2 → 20% of slots replaced), emitted as
        alternating upsert/delete batches of ``batch`` evenly spaced over
        the window.  ``hub_kill_at`` injects one adversarial hub-kill of
        ``hub_kill_k`` nodes at that offset; ``relink_every`` schedules
        periodic repair passes of ``relink_budget`` nodes."""
        from repro.data import mips_dataset

        rng = np.random.default_rng(seed)
        n_mut = max(int(round(turnover * n_items / max(batch, 1))), 1)
        events: List[ChurnEvent] = []
        span = duration_s / max(2 * n_mut, 1)
        t = start_t
        for i in range(n_mut):
            # Delete-before-upsert keeps the net live count flat and lets
            # the upsert reuse the slots the delete just freed.
            t += span
            events.append(ChurnEvent(
                t=t, kind="delete", count=batch,
                seed=int(rng.integers(0, 2**31 - 1)),
            ))
            t += span
            payload = mips_dataset(
                batch, dim, profile, seed=int(rng.integers(0, 2**31 - 1)),
            )
            events.append(ChurnEvent(t=t, kind="upsert", items=payload))
        if hub_kill_at is not None and hub_kill_k > 0:
            events.append(ChurnEvent(
                t=start_t + hub_kill_at, kind="hub_kill", count=hub_kill_k,
            ))
        if relink_every is not None and relink_budget > 0:
            t = start_t + relink_every
            while t < start_t + duration_s + 1e-9:
                events.append(ChurnEvent(
                    t=t, kind="relink", count=relink_budget,
                ))
                t += relink_every
        events.sort(key=lambda e: (e.t, e.kind))
        return ChurnTrace(events=tuple(events))


def apply_churn_event(m: MutableIndex, ev: ChurnEvent) -> Dict[str, float]:
    """Apply one event; returns a small summary dict (for logging/stats)."""
    if ev.kind == "upsert":
        slots = m.upsert(ev.items)
        return {"kind": ev.kind, "n": int(len(slots))}
    if ev.kind == "delete":
        rng = np.random.default_rng(ev.seed)
        pool = m.live_ids()
        n = min(int(ev.count), len(pool) - 1)
        if n <= 0:
            return {"kind": ev.kind, "n": 0}
        ids = rng.choice(pool, size=n, replace=False)
        m.delete(ids)
        return {"kind": ev.kind, "n": n}
    if ev.kind == "hub_kill":
        ids = m.kill_hubs(ev.count)
        return {"kind": ev.kind, "n": int(len(ids))}
    if ev.kind == "relink":
        n = m.relink(ev.count)
        return {"kind": ev.kind, "n": n}
    raise ValueError(f"unknown churn event kind {ev.kind!r}")
