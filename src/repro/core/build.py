"""Batched NSW construction (paper Algorithm 2), TPU-native.

The reference builds the graph by strictly sequential insertion.  We insert in
mini-batches: every item of a batch searches the *frozen* current graph for
its top-M neighbors (the standard parallel-HNSW approximation), then all edges
are committed functionally:

  forward edges   adj[new] = top-M search results (one row write per item)
  reverse edges   HNSW-style "add reverse link and shrink to M": implemented
                  as a *segmented top-M merge* — a sort-based algorithm (the
                  same sort/segment machinery MoE dispatch uses) instead of
                  per-node locks:
                    1. build an edge table = (existing edges of every touched
                       target) ∪ (new reverse candidates)
                    2. lex-sort by (target, neighbor) to drop duplicate pairs
                    3. lex-sort by (target, -score), rank within segment,
                       keep rank < M, scatter rows back

Note on faithfulness: Algorithm 2 as printed uses directed edges only; a
literal directed build is non-navigable from a fixed entry vertex (see
DESIGN.md §2).  Morozov & Babenko's released code (HNSW) adds pruned reverse
links; ``reverse_links=True`` (default) matches the code the paper measured,
``False`` reproduces the printed algorithm.

Build backends (``build_backend=``, see DESIGN.md §6):
  "host"  — Python loop over insertion batches; one jit-compiled
            find+commit per batch with a host round-trip in between.
  "scan"  — the whole insertion schedule is a single jit-compiled
            ``lax.scan`` whose carry is the adjacency (donated, so XLA
            updates it in place); zero per-batch host round-trips.  The
            tail batch is padded and masked, which keeps the resulting
            graph bit-identical to the host loop (tests/test_build_parity).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphIndex, empty_graph
from repro.core.search import beam_search
from repro.core.similarity import Similarity, pair_scores, prepare_items

NEG_INF = jnp.float32(-jnp.inf)

BUILD_BACKENDS = ("host", "scan")


# ---------------------------------------------------------------------------
# Edge commit
# ---------------------------------------------------------------------------


def _segmented_topM_merge(
    adj: jax.Array,
    items: jax.Array,
    targets: jax.Array,   # [E] int32 reverse-edge targets (-1 invalid)
    cands: jax.Array,     # [E] int32 candidate neighbors (the new items)
    scores: jax.Array,    # [E] fp32 s(target, cand)
) -> jax.Array:
    """Merge reverse-edge candidates into the adjacency rows of ``targets``,
    keeping each row's top-M by similarity.  Fully vectorized."""
    n, m = adj.shape
    e = targets.shape[0]
    big = jnp.int32(n + 1)

    # --- existing edges of touched targets (contributed once per target) ----
    order = jnp.argsort(jnp.where(targets >= 0, targets, big))
    t_s = targets[order]
    c_s = cands[order]
    s_s = scores[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), t_s[1:] != t_s[:-1]]
    ) & (t_s >= 0)

    safe_t = jnp.maximum(t_s, 0)
    ex_ids = adj[safe_t]                                   # [E, M]
    ex_valid = (ex_ids >= 0) & first[:, None]
    ex_vecs = items[jnp.maximum(ex_ids, 0)]                # [E, M, d]
    t_vecs = items[safe_t]                                 # [E, d]
    ex_scores = jnp.einsum(
        "ed,emd->em", t_vecs, ex_vecs, preferred_element_type=jnp.float32
    )

    # --- edge table ---------------------------------------------------------
    tab_t = jnp.concatenate([t_s, jnp.broadcast_to(t_s[:, None], (e, m)).reshape(-1)])
    tab_c = jnp.concatenate([c_s, ex_ids.reshape(-1)])
    tab_s = jnp.concatenate([s_s, ex_scores.reshape(-1)])
    tab_v = jnp.concatenate([t_s >= 0, ex_valid.reshape(-1)])
    tab_v &= tab_c >= 0

    # --- pass 1: drop duplicate (target, neighbor) pairs --------------------
    k1 = jnp.where(tab_v, tab_t, big)
    k2 = jnp.where(tab_v, tab_c, big)
    k1, k2, tab_t, tab_c, tab_s, tab_v = jax.lax.sort(
        (k1, k2, tab_t, tab_c, tab_s, tab_v), num_keys=2, is_stable=True
    )
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (k1[1:] == k1[:-1]) & (k2[1:] == k2[:-1])]
    )
    tab_v &= ~dup

    # --- pass 2: rank by score within each target segment -------------------
    k1 = jnp.where(tab_v, tab_t, big)
    nk = jnp.where(tab_v, -tab_s, jnp.float32(jnp.inf))
    k1, nk, tab_t, tab_c, tab_v = jax.lax.sort(
        (k1, nk, tab_t, tab_c, tab_v), num_keys=2, is_stable=True
    )
    r = tab_t.shape[0]
    idx = jnp.arange(r, dtype=jnp.int32)
    seg_first = jnp.concatenate([jnp.ones((1,), bool), k1[1:] != k1[:-1]])
    seg_start = jax.lax.cummax(jnp.where(seg_first, idx, 0))
    rank = idx - seg_start
    keep = tab_v & (rank < m)

    # --- scatter rows back (touched rows fully rewritten) --------------------
    adj_pad = jnp.concatenate([adj, jnp.full((1, m), -1, adj.dtype)], axis=0)
    row = jnp.where(first, safe_t, n)
    adj_pad = adj_pad.at[row].set(-1)  # clear touched rows (dummy row n absorbs)
    wr = jnp.where(keep, tab_t, n)
    wc = jnp.where(keep, rank, 0)
    adj_pad = adj_pad.at[wr, wc].set(jnp.where(keep, tab_c, -1))
    return adj_pad[:n]


@functools.partial(jax.jit, static_argnames=("reverse_links",))
def commit_batch(
    graph: GraphIndex,
    batch_ids: jax.Array,    # [B] int32 ids being inserted
    nbr_ids: jax.Array,      # [B, M] int32 chosen neighbors (-1 padded)
    nbr_scores: jax.Array,   # [B, M] fp32
    norms: jax.Array,        # [N] fp32 (for entry maintenance)
    valid: Optional[jax.Array] = None,  # [B] bool, False = pad row (skipped)
    reverse_links: bool = True,
) -> GraphIndex:
    """Write one insertion batch into the graph (forward + reverse edges) and
    advance size/entry.  ``valid`` masks pad rows of a fixed-shape batch (the
    scan backend's tail batch); masked rows contribute no edges and no size
    advance, so a padded batch commits bit-identically to its ragged slice.
    Callers that pass ``valid`` must already have masked pad rows of
    ``nbr_ids`` to -1 (keeps them out of the reverse-edge table)."""
    n, m = graph.adj.shape
    b = batch_ids.shape[0]

    if valid is None:
        adj = graph.adj.at[batch_ids].set(nbr_ids)
        size = jnp.maximum(graph.size, batch_ids.max() + 1)
    else:
        rows = jnp.where(valid, batch_ids, n)  # out-of-range rows are dropped
        adj = graph.adj.at[rows].set(nbr_ids, mode="drop")
        size = jnp.maximum(graph.size, jnp.max(jnp.where(valid, batch_ids, -1)) + 1)

    if reverse_links:
        targets = nbr_ids.reshape(-1)
        cands = jnp.broadcast_to(batch_ids[:, None], (b, m)).reshape(-1)
        scores = nbr_scores.reshape(-1)
        adj = _segmented_topM_merge(adj, graph.items, targets, cands, scores)

    inserted = jnp.arange(n) < size
    entry = jnp.argmax(jnp.where(inserted, norms, -jnp.inf)).astype(jnp.int32)
    return GraphIndex(adj=adj, items=graph.items, size=size, entry=entry)


# ---------------------------------------------------------------------------
# Neighbor finding
# ---------------------------------------------------------------------------


def _bootstrap_neighbors(batch_items: jax.Array, max_degree: int):
    """Sequential-prefix exact neighbors inside the first batch: item i may
    only connect to items 0..i-1 (mimics sequential insertion)."""
    b = batch_items.shape[0]
    s = pair_scores(batch_items, batch_items)
    i = jnp.arange(b)
    mask = i[None, :] < i[:, None]  # j strictly before i
    s = jnp.where(mask, s, NEG_INF)
    k = min(max_degree, b)
    vals, idxs = jax.lax.top_k(s, k)
    ids = jnp.where(vals > NEG_INF, idxs, -1).astype(jnp.int32)
    pad = max_degree - k
    if pad:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return ids, vals


@functools.partial(
    jax.jit, static_argnames=("max_degree", "ef", "max_steps", "backend")
)
def find_neighbors(
    graph: GraphIndex,
    batch_items: jax.Array,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
):
    """Algorithm-1 search of the current graph for each batch item's top-M."""
    b = batch_items.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    res = beam_search(
        graph,
        batch_items,
        init,
        pool_size=ef,
        max_steps=max_steps,
        k=max_degree,
        backend=backend,
    )
    ids = jnp.where(res.scores > NEG_INF, res.ids, -1)
    return ids, res.scores


# ---------------------------------------------------------------------------
# Build drivers
# ---------------------------------------------------------------------------


def batch_schedule(n: int, insert_batch: int):
    """The insertion schedule shared by every build backend.

    Returns ``(first, batch_ids, batch_valid)``: the bootstrap-batch size and
    the ``[num_batches, insert_batch]`` id / validity arrays of the remaining
    batches (tail padded with clamped ids, ``valid=False``).  The scan build
    consumes this directly; the host loops iterate start/stop ranges that
    match it by construction — tests/test_build_parity.py pins the two
    bit-identical, so edits here must keep them in lockstep.
    """
    first = min(insert_batch, n)
    starts = np.arange(first, n, insert_batch, dtype=np.int64)
    ids = starts[:, None] + np.arange(insert_batch, dtype=np.int64)[None, :]
    valid = ids < n
    ids = np.minimum(ids, n - 1).astype(np.int32)
    return first, ids, valid


def bootstrap_graph(
    prepared: jax.Array,
    norms: jax.Array,
    *,
    max_degree: int,
    insert_batch: int,
    reverse_links: bool,
) -> GraphIndex:
    """Empty graph + the sequential-prefix first batch (shared by backends)."""
    n = prepared.shape[0]
    graph = empty_graph(prepared, max_degree)
    first = min(insert_batch, n)
    ids0 = jnp.arange(first, dtype=jnp.int32)
    nbr0, sc0 = _bootstrap_neighbors(prepared[:first], max_degree)
    return commit_batch(graph, ids0, nbr0, sc0, norms, reverse_links=reverse_links)


def _scan_insert(
    adj: jax.Array,
    size: jax.Array,
    entry: jax.Array,
    prepared: jax.Array,
    norms: jax.Array,
    batch_ids: jax.Array,    # [T, B] int32 (tail clamped)
    batch_valid: jax.Array,  # [T, B] bool
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    reverse_links: bool,
    backend: str,
):
    """All remaining insertion batches as one ``lax.scan``.

    Carry = (adj, size, entry); items/norms are closed over (never copied).
    Pad rows of the tail batch run real (masked-out) walks, and the done
    flag of ``beam_search`` freezes finished queries, so every valid row's
    neighbors — and therefore the committed graph — are bit-identical to
    the host loop's ragged batches.
    """

    def body(carry, xs):
        adj, size, entry = carry
        bids, vmask = xs
        graph = GraphIndex(adj=adj, items=prepared, size=size, entry=entry)
        nbr, sc = find_neighbors(
            graph,
            jnp.take(prepared, bids, axis=0),
            max_degree=max_degree,
            ef=ef,
            max_steps=max_steps,
            backend=backend,
        )
        nbr = jnp.where(vmask[:, None], nbr, -1)
        sc = jnp.where(vmask[:, None], sc, NEG_INF)
        g = commit_batch(
            graph, bids, nbr, sc, norms, valid=vmask, reverse_links=reverse_links
        )
        return (g.adj, g.size, g.entry), None

    (adj, size, entry), _ = jax.lax.scan(
        body, (adj, size, entry), (batch_ids, batch_valid)
    )
    return adj, size, entry


# Single-index entry point: the adjacency carry is donated, so the only full
# [N, M] buffer alive during the build is the one XLA updates in place.
_scan_insert_jit = functools.partial(
    jax.jit,
    static_argnames=("max_degree", "ef", "max_steps", "reverse_links", "backend"),
    donate_argnums=(0,),
)(_scan_insert)


def scan_build_arrays(
    prepared: jax.Array,
    norms: jax.Array,
    batch_ids: jax.Array,
    batch_valid: jax.Array,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    insert_batch: int,
    reverse_links: bool,
    backend: str,
):
    """Fully-traced build (bootstrap + scan) -> (adj, size, entry).

    Pure function of arrays: ``build_sharded`` vmaps it over a leading shard
    axis so all P shard graphs build inside one device program.
    """
    g = bootstrap_graph(
        prepared,
        norms,
        max_degree=max_degree,
        insert_batch=insert_batch,
        reverse_links=reverse_links,
    )
    return _scan_insert(
        g.adj, g.size, g.entry, prepared, norms, batch_ids, batch_valid,
        max_degree=max_degree, ef=ef, max_steps=max_steps,
        reverse_links=reverse_links, backend=backend,
    )


def build_graph(
    items: jax.Array,
    *,
    similarity: Similarity = Similarity.INNER_PRODUCT,
    max_degree: int = 16,
    ef_construction: int = 32,
    insert_batch: int = 128,
    reverse_links: bool = True,
    max_steps: Optional[int] = None,
    neighbor_fn: Optional[Callable] = None,
    backend: str = "reference",
    build_backend: str = "host",
    progress: bool = False,
) -> GraphIndex:
    """Build an NSW proximity graph for ``items`` under ``similarity``.

    ``neighbor_fn(graph, batch_items) -> (ids, scores)`` overrides the
    neighbor search — ip-NSW+ passes its own Algorithm-3-based finder.
    ``backend`` selects the walk step backend for insertion searches
    (see search.STEP_BACKENDS); ``build_backend`` selects the insertion
    driver ("host" Python loop | "scan" single-compile lax.scan, see
    BUILD_BACKENDS and DESIGN.md §6).
    """
    if build_backend not in BUILD_BACKENDS:
        raise ValueError(
            f"build_backend must be one of {BUILD_BACKENDS}, got {build_backend!r}"
        )
    prepared = prepare_items(jnp.asarray(items), similarity)
    n = prepared.shape[0]
    norms = jnp.linalg.norm(prepared, axis=-1)
    steps = max_steps if max_steps is not None else 2 * ef_construction

    if build_backend == "scan":
        if neighbor_fn is not None:
            raise ValueError(
                "build_backend='scan' traces the standard Algorithm-2 finder "
                "into the scan body and cannot honor neighbor_fn; use "
                "build_backend='host' for custom finders"
            )
        graph = bootstrap_graph(
            prepared, norms, max_degree=max_degree, insert_batch=insert_batch,
            reverse_links=reverse_links,
        )
        _, bids, valid = batch_schedule(n, insert_batch)
        if bids.shape[0]:
            adj, size, entry = _scan_insert_jit(
                graph.adj, graph.size, graph.entry, prepared, norms,
                jnp.asarray(bids), jnp.asarray(valid),
                max_degree=max_degree, ef=ef_construction, max_steps=steps,
                reverse_links=reverse_links, backend=backend,
            )
            graph = GraphIndex(adj=adj, items=prepared, size=size, entry=entry)
        return graph

    graph = bootstrap_graph(
        prepared, norms, max_degree=max_degree, insert_batch=insert_batch,
        reverse_links=reverse_links,
    )

    start = min(insert_batch, n)
    while start < n:
        stop = min(start + insert_batch, n)
        bids = jnp.arange(start, stop, dtype=jnp.int32)
        batch_items = prepared[start:stop]
        if neighbor_fn is None:
            nbr, sc = find_neighbors(
                graph,
                batch_items,
                max_degree=max_degree,
                ef=ef_construction,
                max_steps=steps,
                backend=backend,
            )
        else:
            nbr, sc = neighbor_fn(graph, batch_items)
        graph = commit_batch(graph, bids, nbr, sc, norms, reverse_links=reverse_links)
        if progress and (start // insert_batch) % 20 == 0:
            print(f"  inserted {stop}/{n}")
        start = stop

    return graph
