"""Batched NSW construction (paper Algorithm 2), TPU-native.

The reference builds the graph by strictly sequential insertion.  We insert in
mini-batches: every item of a batch searches the *frozen* current graph for
its top-M neighbors (the standard parallel-HNSW approximation), then all edges
are committed functionally:

  forward edges   adj[new] = top-M search results (one row write per item)
  reverse edges   HNSW-style "add reverse link and shrink to M": implemented
                  as a *segmented top-M merge* instead of per-node locks,
                  behind a pluggable commit backend (``commit_backend=``,
                  see COMMIT_BACKENDS and DESIGN.md §7):
                    "reference" — kernels/commit_merge/ref.py: sort-based
                                  (the same sort/segment machinery MoE
                                  dispatch uses), two device-wide lex-sorts
                                  over the E·(M+1) edge table
                    "pallas"    — kernels/commit_merge/ops.py: the fused
                                  kernel; one E-row bucketing sort, then
                                  every touched row is gathered, rescored,
                                  deduped and re-ranked on-chip, with
                                  ``commit_tile`` targets merged per grid
                                  step (interpret mode off-TPU)

``commit_tile`` sizes the fused commit kernel's grid tiles ("auto" resolves
via the norm-skew planner, kernels/commit_merge/ops.resolve_commit_tile);
build drivers resolve it on host before tracing so the scan backend gets a
static tile honoring the heuristic.

Note on faithfulness: Algorithm 2 as printed uses directed edges only; a
literal directed build is non-navigable from a fixed entry vertex (see
DESIGN.md §2).  Morozov & Babenko's released code (HNSW) adds pruned reverse
links; ``reverse_links=True`` (default) matches the code the paper measured,
``False`` reproduces the printed algorithm.

Build backends (``build_backend=``, see DESIGN.md §6):
  "host"  — Python loop over insertion batches; one jit-compiled
            find+commit per batch with a host round-trip in between.
  "scan"  — the whole insertion schedule is a single jit-compiled
            ``lax.scan`` whose carry is the adjacency (donated, so XLA
            updates it in place); zero per-batch host round-trips.  The
            tail batch is padded and masked, which keeps the resulting
            graph bit-identical to the host loop (tests/test_build_parity).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphIndex, empty_graph
from repro.core.search import STEP_BACKENDS, beam_search
from repro.core.similarity import Similarity, pair_scores, prepare_items
from repro.kernels.commit_merge import (
    commit_merge,
    commit_merge_ref,
    resolve_commit_tile,
)

NEG_INF = jnp.float32(-jnp.inf)

BUILD_BACKENDS = ("host", "scan")
COMMIT_BACKENDS = ("reference", "pallas")


# ---------------------------------------------------------------------------
# Edge commit
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("reverse_links", "commit_backend", "commit_tile"),
)
def commit_batch(
    graph: GraphIndex,
    batch_ids: jax.Array,    # [B] int32 ids being inserted
    nbr_ids: jax.Array,      # [B, M] int32 chosen neighbors (-1 padded)
    nbr_scores: jax.Array,   # [B, M] fp32
    norms: jax.Array,        # [N] fp32 (for entry maintenance)
    valid: Optional[jax.Array] = None,  # [B] bool, False = pad row (skipped)
    reverse_links: bool = True,
    commit_backend: str = "reference",
    commit_tile: Union[int, str] = "auto",
) -> GraphIndex:
    """Write one insertion batch into the graph (forward + reverse edges) and
    advance size/entry.  ``valid`` masks pad rows of a fixed-shape batch (the
    scan backend's tail batch); masked rows contribute no edges and no size
    advance, so a padded batch commits bit-identically to its ragged slice.
    Callers that pass ``valid`` must already have masked pad rows of
    ``nbr_ids`` to -1 (keeps them out of the reverse-edge table).

    ``commit_backend`` selects the reverse-link merge implementation
    (COMMIT_BACKENDS; both are bit-identical — tests/test_kernel_parity.py).
    ``commit_tile`` sizes the fused kernel's grid tiles (ignored by the
    reference backend; every tile commits the identical graph).  It must be
    static: pass an int resolved by resolve_commit_tile to honor the
    norm-skew heuristic — the bare ``"auto"`` here resolves without data to
    DEFAULT_COMMIT_TILE.

    Entry maintenance is an O(B) compare of the batch's max-norm insert
    against the carried ``graph.entry_norm`` — equivalent to the historical
    full [N] masked argmax whenever ids are inserted in ascending order (all
    build drivers; pinned in tests/test_build_parity.py)."""
    if commit_backend not in COMMIT_BACKENDS:
        raise ValueError(
            f"commit_backend must be one of {COMMIT_BACKENDS}, "
            f"got {commit_backend!r}"
        )
    resolve_commit_tile(commit_tile)  # eager knob validation (value unused
    #                                   by the reference backend)
    n, m = graph.adj.shape
    b = batch_ids.shape[0]

    if valid is None:
        adj = graph.adj.at[batch_ids].set(nbr_ids)
        size = jnp.maximum(graph.size, batch_ids.max() + 1)
    else:
        rows = jnp.where(valid, batch_ids, n)  # out-of-range rows are dropped
        adj = graph.adj.at[rows].set(nbr_ids, mode="drop")
        size = jnp.maximum(graph.size, jnp.max(jnp.where(valid, batch_ids, -1)) + 1)

    if reverse_links:
        targets = nbr_ids.reshape(-1)
        cands = jnp.broadcast_to(batch_ids[:, None], (b, m)).reshape(-1)
        scores = nbr_scores.reshape(-1)
        if commit_backend == "pallas":
            adj = commit_merge(
                adj, graph.items, targets, cands, scores, max_cands=b,
                commit_tile=commit_tile,
            )
        else:
            adj = commit_merge_ref(adj, graph.items, targets, cands, scores)

    b_norms = jnp.take(norms, batch_ids)
    if valid is not None:
        b_norms = jnp.where(valid, b_norms, NEG_INF)
    best = jnp.argmax(b_norms)  # first max = smallest id (ids ascend in-batch)
    prev_norm = (
        graph.entry_norm if graph.entry_norm is not None
        else jnp.take(norms, graph.entry)  # legacy graphs without the carry
    ).astype(jnp.float32)
    take = b_norms[best] > prev_norm
    entry = jnp.where(take, batch_ids[best], graph.entry).astype(jnp.int32)
    entry_norm = jnp.where(take, b_norms[best], prev_norm)
    return GraphIndex(
        adj=adj, items=graph.items, size=size, entry=entry,
        entry_norm=entry_norm,
    )


# ---------------------------------------------------------------------------
# Neighbor finding
# ---------------------------------------------------------------------------


def _bootstrap_neighbors(batch_items: jax.Array, max_degree: int):
    """Sequential-prefix exact neighbors inside the first batch: item i may
    only connect to items 0..i-1 (mimics sequential insertion)."""
    b = batch_items.shape[0]
    s = pair_scores(batch_items, batch_items)
    i = jnp.arange(b)
    mask = i[None, :] < i[:, None]  # j strictly before i
    s = jnp.where(mask, s, NEG_INF)
    k = min(max_degree, b)
    vals, idxs = jax.lax.top_k(s, k)
    ids = jnp.where(vals > NEG_INF, idxs, -1).astype(jnp.int32)
    pad = max_degree - k
    if pad:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return ids, vals


@functools.partial(
    jax.jit, static_argnames=("max_degree", "ef", "max_steps", "backend")
)
def find_neighbors(
    graph: GraphIndex,
    batch_items: jax.Array,
    live: Optional[jax.Array] = None,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
):
    """Algorithm-1 search of the current graph for each batch item's top-M.

    ``live`` ([N] bool) is the mutation layer's tombstone mask: upsert and
    relink pass it so the chosen neighbors are guaranteed live — the walk
    still routes through tombstones, but a dead node must never become an
    out-edge of fresh content (it would re-spend the dead-edge budget the
    repair pass exists to pay down).  Fresh builds leave it None."""
    b = batch_items.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    res = beam_search(
        graph,
        batch_items,
        init,
        pool_size=ef,
        max_steps=max_steps,
        k=max_degree,
        backend=backend,
        live=live,
    )
    ids = jnp.where(res.scores > NEG_INF, res.ids, -1)
    return ids, res.scores


# ---------------------------------------------------------------------------
# Build drivers
# ---------------------------------------------------------------------------


def batch_schedule(n: int, insert_batch: int):
    """The insertion schedule shared by every build backend.

    Returns ``(first, batch_ids, batch_valid)``: the bootstrap-batch size and
    the ``[num_batches, insert_batch]`` id / validity arrays of the remaining
    batches (tail padded with clamped ids, ``valid=False``).  The scan build
    consumes this directly; the host loops iterate start/stop ranges that
    match it by construction — tests/test_build_parity.py pins the two
    bit-identical, so edits here must keep them in lockstep.
    """
    first = min(insert_batch, n)
    starts = np.arange(first, n, insert_batch, dtype=np.int64)
    ids = starts[:, None] + np.arange(insert_batch, dtype=np.int64)[None, :]
    valid = ids < n
    ids = np.minimum(ids, n - 1).astype(np.int32)
    return first, ids, valid


def bootstrap_graph(
    prepared: jax.Array,
    norms: jax.Array,
    *,
    max_degree: int,
    insert_batch: int,
    reverse_links: bool,
    commit_backend: str = "reference",
    commit_tile: Union[int, str] = "auto",
) -> GraphIndex:
    """Empty graph + the sequential-prefix first batch (shared by backends)."""
    n = prepared.shape[0]
    graph = empty_graph(prepared, max_degree)
    first = min(insert_batch, n)
    ids0 = jnp.arange(first, dtype=jnp.int32)
    nbr0, sc0 = _bootstrap_neighbors(prepared[:first], max_degree)
    return commit_batch(
        graph, ids0, nbr0, sc0, norms, reverse_links=reverse_links,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )


def _scan_insert(
    adj: jax.Array,
    size: jax.Array,
    entry: jax.Array,
    entry_norm: jax.Array,
    prepared: jax.Array,
    norms: jax.Array,
    batch_ids: jax.Array,    # [T, B] int32 (tail clamped)
    batch_valid: jax.Array,  # [T, B] bool
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    reverse_links: bool,
    backend: str,
    commit_backend: str,
    commit_tile: Union[int, str],
):
    """All remaining insertion batches as one ``lax.scan``.

    Carry = (adj, size, entry, entry_norm); items/norms are closed over
    (never copied).  Pad rows of the tail batch run real (masked-out) walks,
    and the done flag of ``beam_search`` freezes finished queries, so every
    valid row's neighbors — and therefore the committed graph — are
    bit-identical to the host loop's ragged batches.
    """

    def body(carry, xs):
        adj, size, entry, entry_norm = carry
        bids, vmask = xs
        graph = GraphIndex(
            adj=adj, items=prepared, size=size, entry=entry,
            entry_norm=entry_norm,
        )
        nbr, sc = find_neighbors(
            graph,
            jnp.take(prepared, bids, axis=0),
            max_degree=max_degree,
            ef=ef,
            max_steps=max_steps,
            backend=backend,
        )
        nbr = jnp.where(vmask[:, None], nbr, -1)
        sc = jnp.where(vmask[:, None], sc, NEG_INF)
        g = commit_batch(
            graph, bids, nbr, sc, norms, valid=vmask,
            reverse_links=reverse_links, commit_backend=commit_backend,
            commit_tile=commit_tile,
        )
        return (g.adj, g.size, g.entry, g.entry_norm), None

    (adj, size, entry, entry_norm), _ = jax.lax.scan(
        body, (adj, size, entry, entry_norm), (batch_ids, batch_valid)
    )
    return adj, size, entry, entry_norm


# Single-index entry point: the adjacency carry is donated, so the only full
# [N, M] buffer alive during the build is the one XLA updates in place.
_scan_insert_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "max_degree", "ef", "max_steps", "reverse_links", "backend",
        "commit_backend", "commit_tile",
    ),
    donate_argnums=(0,),
)(_scan_insert)


def scan_build_arrays(
    prepared: jax.Array,
    norms: jax.Array,
    batch_ids: jax.Array,
    batch_valid: jax.Array,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    insert_batch: int,
    reverse_links: bool,
    backend: str,
    commit_backend: str = "reference",
    commit_tile: Union[int, str] = "auto",
):
    """Fully-traced build (bootstrap + scan) -> (adj, size, entry, entry_norm).

    Pure function of arrays: ``build_sharded`` vmaps it over a leading shard
    axis so all P shard graphs build inside one device program.
    ``commit_tile`` must already be static (int or the planner's "auto"
    fallback) — resolve it on host before tracing to use the norm-skew
    heuristic.
    """
    g = bootstrap_graph(
        prepared,
        norms,
        max_degree=max_degree,
        insert_batch=insert_batch,
        reverse_links=reverse_links,
        commit_backend=commit_backend,
        commit_tile=commit_tile,
    )
    return _scan_insert(
        g.adj, g.size, g.entry, g.entry_norm, prepared, norms,
        batch_ids, batch_valid,
        max_degree=max_degree, ef=ef, max_steps=max_steps,
        reverse_links=reverse_links, backend=backend,
        commit_backend=commit_backend, commit_tile=commit_tile,
    )


def build_graph(
    items: jax.Array,
    *,
    similarity: Similarity = Similarity.INNER_PRODUCT,
    max_degree: int = 16,
    ef_construction: int = 32,
    insert_batch: int = 128,
    reverse_links: bool = True,
    max_steps: Optional[int] = None,
    neighbor_fn: Optional[Callable] = None,
    backend: str = "reference",
    build_backend: str = "host",
    commit_backend: str = "reference",
    commit_tile: Union[int, str] = "auto",
    progress: bool = False,
) -> GraphIndex:
    """Build an NSW proximity graph for ``items`` under ``similarity``.

    ``neighbor_fn(graph, batch_items) -> (ids, scores)`` overrides the
    neighbor search — ip-NSW+ passes its own Algorithm-3-based finder.
    ``backend`` selects the walk step backend for insertion searches
    (see search.STEP_BACKENDS); ``build_backend`` selects the insertion
    driver ("host" Python loop | "scan" single-compile lax.scan, see
    BUILD_BACKENDS and DESIGN.md §6); ``commit_backend`` selects the
    reverse-link merge kernel (COMMIT_BACKENDS, DESIGN.md §7) and
    ``commit_tile`` its grid tiling — a positive int, or ``"auto"`` to let
    the planner pick the tile from the norm skew of ``items`` (resolved
    here, on host, so both drivers — including the fully-traced scan — see
    the same static tile).  All four are validated eagerly, before any
    build work starts.

    There is deliberately NO ``storage=`` knob here: construction always
    walks and scores fp32 items, because edge-selection error compounds
    into a permanently worse graph while search-time quantization error is
    repaired per query by the exact rerank.  The int8 item store is derived
    once from the frozen items post-build (storage.make_store; the index
    classes own that step — DESIGN.md §8).
    """
    if build_backend not in BUILD_BACKENDS:
        raise ValueError(
            f"build_backend must be one of {BUILD_BACKENDS}, got {build_backend!r}"
        )
    if backend not in STEP_BACKENDS:
        raise ValueError(
            f"backend must be one of {STEP_BACKENDS}, got {backend!r}"
        )
    if commit_backend not in COMMIT_BACKENDS:
        raise ValueError(
            f"commit_backend must be one of {COMMIT_BACKENDS}, "
            f"got {commit_backend!r}"
        )
    prepared = prepare_items(jnp.asarray(items), similarity)
    n = prepared.shape[0]
    norms = jnp.linalg.norm(prepared, axis=-1)
    commit_tile = resolve_commit_tile(
        commit_tile, e=insert_batch * max_degree, norms=norms
    )
    steps = max_steps if max_steps is not None else 2 * ef_construction
    # Phase spans report into the process-global obs registry (repro.obs
    # never imports repro.core, so this is cycle-free).  Spans measure the
    # DRIVER's wall time only: jax dispatch is async and no block is added
    # here, so device work may overlap a span — the numbers locate where
    # build time goes, they are not a device-time profile.
    from repro.obs.registry import get_registry

    reg = get_registry()

    if build_backend == "scan":
        if neighbor_fn is not None:
            raise ValueError(
                "build_backend='scan' traces the standard Algorithm-2 finder "
                "into the scan body and cannot honor neighbor_fn; use "
                "build_backend='host' for custom finders"
            )
        with reg.span("build_bootstrap", "bootstrap batch (exact top-k)"):
            graph = bootstrap_graph(
                prepared, norms, max_degree=max_degree,
                insert_batch=insert_batch, reverse_links=reverse_links,
                commit_backend=commit_backend, commit_tile=commit_tile,
            )
        _, bids, valid = batch_schedule(n, insert_batch)
        if bids.shape[0]:
            with reg.span("build_insert",
                          "insertion driver (dispatch only on scan)"):
                adj, size, entry, entry_norm = _scan_insert_jit(
                    graph.adj, graph.size, graph.entry, graph.entry_norm,
                    prepared, norms,
                    jnp.asarray(bids), jnp.asarray(valid),
                    max_degree=max_degree, ef=ef_construction,
                    max_steps=steps,
                    reverse_links=reverse_links, backend=backend,
                    commit_backend=commit_backend, commit_tile=commit_tile,
                )
            graph = GraphIndex(
                adj=adj, items=prepared, size=size, entry=entry,
                entry_norm=entry_norm,
            )
        return graph

    with reg.span("build_bootstrap", "bootstrap batch (exact top-k)"):
        graph = bootstrap_graph(
            prepared, norms, max_degree=max_degree, insert_batch=insert_batch,
            reverse_links=reverse_links, commit_backend=commit_backend,
            commit_tile=commit_tile,
        )

    start = min(insert_batch, n)
    with reg.span("build_insert", "insertion driver (dispatch only on scan)"):
        while start < n:
            stop = min(start + insert_batch, n)
            bids = jnp.arange(start, stop, dtype=jnp.int32)
            batch_items = prepared[start:stop]
            if neighbor_fn is None:
                nbr, sc = find_neighbors(
                    graph,
                    batch_items,
                    max_degree=max_degree,
                    ef=ef_construction,
                    max_steps=steps,
                    backend=backend,
                )
            else:
                nbr, sc = neighbor_fn(graph, batch_items)
            graph = commit_batch(
                graph, bids, nbr, sc, norms, reverse_links=reverse_links,
                commit_backend=commit_backend, commit_tile=commit_tile,
            )
            if progress and (start // insert_batch) % 20 == 0:
                print(f"  inserted {stop}/{n}")
            start = stop

    return graph
