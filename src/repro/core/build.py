"""Batched NSW construction (paper Algorithm 2), TPU-native.

The reference builds the graph by strictly sequential insertion.  We insert in
mini-batches: every item of a batch searches the *frozen* current graph for
its top-M neighbors (the standard parallel-HNSW approximation), then all edges
are committed functionally:

  forward edges   adj[new] = top-M search results (one row write per item)
  reverse edges   HNSW-style "add reverse link and shrink to M": implemented
                  as a *segmented top-M merge* — a sort-based algorithm (the
                  same sort/segment machinery MoE dispatch uses) instead of
                  per-node locks:
                    1. build an edge table = (existing edges of every touched
                       target) ∪ (new reverse candidates)
                    2. lex-sort by (target, neighbor) to drop duplicate pairs
                    3. lex-sort by (target, -score), rank within segment,
                       keep rank < M, scatter rows back

Note on faithfulness: Algorithm 2 as printed uses directed edges only; a
literal directed build is non-navigable from a fixed entry vertex (see
DESIGN.md §2).  Morozov & Babenko's released code (HNSW) adds pruned reverse
links; ``reverse_links=True`` (default) matches the code the paper measured,
``False`` reproduces the printed algorithm.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphIndex, empty_graph
from repro.core.search import beam_search
from repro.core.similarity import Similarity, pair_scores, prepare_items

NEG_INF = jnp.float32(-jnp.inf)


# ---------------------------------------------------------------------------
# Edge commit
# ---------------------------------------------------------------------------


def _segmented_topM_merge(
    adj: jax.Array,
    items: jax.Array,
    targets: jax.Array,   # [E] int32 reverse-edge targets (-1 invalid)
    cands: jax.Array,     # [E] int32 candidate neighbors (the new items)
    scores: jax.Array,    # [E] fp32 s(target, cand)
) -> jax.Array:
    """Merge reverse-edge candidates into the adjacency rows of ``targets``,
    keeping each row's top-M by similarity.  Fully vectorized."""
    n, m = adj.shape
    e = targets.shape[0]
    big = jnp.int32(n + 1)

    # --- existing edges of touched targets (contributed once per target) ----
    order = jnp.argsort(jnp.where(targets >= 0, targets, big))
    t_s = targets[order]
    c_s = cands[order]
    s_s = scores[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), t_s[1:] != t_s[:-1]]
    ) & (t_s >= 0)

    safe_t = jnp.maximum(t_s, 0)
    ex_ids = adj[safe_t]                                   # [E, M]
    ex_valid = (ex_ids >= 0) & first[:, None]
    ex_vecs = items[jnp.maximum(ex_ids, 0)]                # [E, M, d]
    t_vecs = items[safe_t]                                 # [E, d]
    ex_scores = jnp.einsum(
        "ed,emd->em", t_vecs, ex_vecs, preferred_element_type=jnp.float32
    )

    # --- edge table ---------------------------------------------------------
    tab_t = jnp.concatenate([t_s, jnp.broadcast_to(t_s[:, None], (e, m)).reshape(-1)])
    tab_c = jnp.concatenate([c_s, ex_ids.reshape(-1)])
    tab_s = jnp.concatenate([s_s, ex_scores.reshape(-1)])
    tab_v = jnp.concatenate([t_s >= 0, ex_valid.reshape(-1)])
    tab_v &= tab_c >= 0

    # --- pass 1: drop duplicate (target, neighbor) pairs --------------------
    k1 = jnp.where(tab_v, tab_t, big)
    k2 = jnp.where(tab_v, tab_c, big)
    k1, k2, tab_t, tab_c, tab_s, tab_v = jax.lax.sort(
        (k1, k2, tab_t, tab_c, tab_s, tab_v), num_keys=2, is_stable=True
    )
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (k1[1:] == k1[:-1]) & (k2[1:] == k2[:-1])]
    )
    tab_v &= ~dup

    # --- pass 2: rank by score within each target segment -------------------
    k1 = jnp.where(tab_v, tab_t, big)
    nk = jnp.where(tab_v, -tab_s, jnp.float32(jnp.inf))
    k1, nk, tab_t, tab_c, tab_v = jax.lax.sort(
        (k1, nk, tab_t, tab_c, tab_v), num_keys=2, is_stable=True
    )
    r = tab_t.shape[0]
    idx = jnp.arange(r, dtype=jnp.int32)
    seg_first = jnp.concatenate([jnp.ones((1,), bool), k1[1:] != k1[:-1]])
    seg_start = jax.lax.cummax(jnp.where(seg_first, idx, 0))
    rank = idx - seg_start
    keep = tab_v & (rank < m)

    # --- scatter rows back (touched rows fully rewritten) --------------------
    adj_pad = jnp.concatenate([adj, jnp.full((1, m), -1, adj.dtype)], axis=0)
    row = jnp.where(first, safe_t, n)
    adj_pad = adj_pad.at[row].set(-1)  # clear touched rows (dummy row n absorbs)
    wr = jnp.where(keep, tab_t, n)
    wc = jnp.where(keep, rank, 0)
    adj_pad = adj_pad.at[wr, wc].set(jnp.where(keep, tab_c, -1))
    return adj_pad[:n]


@functools.partial(jax.jit, static_argnames=("reverse_links",))
def commit_batch(
    graph: GraphIndex,
    batch_ids: jax.Array,    # [B] int32 ids being inserted
    nbr_ids: jax.Array,      # [B, M] int32 chosen neighbors (-1 padded)
    nbr_scores: jax.Array,   # [B, M] fp32
    norms: jax.Array,        # [N] fp32 (for entry maintenance)
    reverse_links: bool = True,
) -> GraphIndex:
    """Write one insertion batch into the graph (forward + reverse edges) and
    advance size/entry."""
    n, m = graph.adj.shape
    b = batch_ids.shape[0]

    adj = graph.adj.at[batch_ids].set(nbr_ids)

    if reverse_links:
        targets = nbr_ids.reshape(-1)
        cands = jnp.broadcast_to(batch_ids[:, None], (b, m)).reshape(-1)
        scores = nbr_scores.reshape(-1)
        adj = _segmented_topM_merge(adj, graph.items, targets, cands, scores)

    size = jnp.maximum(graph.size, batch_ids.max() + 1)
    inserted = jnp.arange(n) < size
    entry = jnp.argmax(jnp.where(inserted, norms, -jnp.inf)).astype(jnp.int32)
    return GraphIndex(adj=adj, items=graph.items, size=size, entry=entry)


# ---------------------------------------------------------------------------
# Neighbor finding
# ---------------------------------------------------------------------------


def _bootstrap_neighbors(batch_items: jax.Array, max_degree: int):
    """Sequential-prefix exact neighbors inside the first batch: item i may
    only connect to items 0..i-1 (mimics sequential insertion)."""
    b = batch_items.shape[0]
    s = pair_scores(batch_items, batch_items)
    i = jnp.arange(b)
    mask = i[None, :] < i[:, None]  # j strictly before i
    s = jnp.where(mask, s, NEG_INF)
    k = min(max_degree, b)
    vals, idxs = jax.lax.top_k(s, k)
    ids = jnp.where(vals > NEG_INF, idxs, -1).astype(jnp.int32)
    pad = max_degree - k
    if pad:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return ids, vals


@functools.partial(
    jax.jit, static_argnames=("max_degree", "ef", "max_steps", "backend")
)
def find_neighbors(
    graph: GraphIndex,
    batch_items: jax.Array,
    *,
    max_degree: int,
    ef: int,
    max_steps: int,
    backend: str = "reference",
):
    """Algorithm-1 search of the current graph for each batch item's top-M."""
    b = batch_items.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    res = beam_search(
        graph,
        batch_items,
        init,
        pool_size=ef,
        max_steps=max_steps,
        k=max_degree,
        backend=backend,
    )
    ids = jnp.where(res.scores > NEG_INF, res.ids, -1)
    return ids, res.scores


# ---------------------------------------------------------------------------
# Build driver
# ---------------------------------------------------------------------------


def build_graph(
    items: jax.Array,
    *,
    similarity: Similarity = Similarity.INNER_PRODUCT,
    max_degree: int = 16,
    ef_construction: int = 32,
    insert_batch: int = 128,
    reverse_links: bool = True,
    max_steps: Optional[int] = None,
    neighbor_fn: Optional[Callable] = None,
    backend: str = "reference",
    progress: bool = False,
) -> GraphIndex:
    """Build an NSW proximity graph for ``items`` under ``similarity``.

    ``neighbor_fn(graph, batch_items) -> (ids, scores)`` overrides the
    neighbor search — ip-NSW+ passes its own Algorithm-3-based finder.
    ``backend`` selects the walk step backend for insertion searches
    (see search.STEP_BACKENDS).
    """
    prepared = prepare_items(jnp.asarray(items), similarity)
    n = prepared.shape[0]
    norms = jnp.linalg.norm(prepared, axis=-1)
    graph = empty_graph(prepared, max_degree)
    steps = max_steps if max_steps is not None else 2 * ef_construction

    first = min(insert_batch, n)
    ids0 = jnp.arange(first, dtype=jnp.int32)
    nbr0, sc0 = _bootstrap_neighbors(prepared[:first], max_degree)
    graph = commit_batch(graph, ids0, nbr0, sc0, norms, reverse_links=reverse_links)

    start = first
    while start < n:
        stop = min(start + insert_batch, n)
        bids = jnp.arange(start, stop, dtype=jnp.int32)
        batch_items = prepared[start:stop]
        if neighbor_fn is None:
            nbr, sc = find_neighbors(
                graph,
                batch_items,
                max_degree=max_degree,
                ef=ef_construction,
                max_steps=steps,
                backend=backend,
            )
        else:
            nbr, sc = neighbor_fn(graph, batch_items)
        graph = commit_batch(graph, bids, nbr, sc, norms, reverse_links=reverse_links)
        if progress and (start // insert_batch) % 20 == 0:
            print(f"  inserted {stop}/{n}")
        start = stop

    return graph
