"""Pure-jnp oracle for the flash_attn kernel (single head)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_head_ref(q, k, v, *, q_offset: int = 0, window=None):
    s, hd = q.shape
    t = k.shape[0]
    logits = (q @ k.T).astype(jnp.float32) / hd**0.5
    qi = q_offset + jnp.arange(s)[:, None]
    ki = jnp.arange(t)[None, :]
    ok = ki <= qi
    if window is not None:
        ok &= ki > qi - window
    logits = jnp.where(ok, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(ok.any(axis=-1, keepdims=True), p, 0.0)
    return (p.astype(v.dtype) @ v).astype(v.dtype)
