"""FlashAttention forward Pallas TPU kernel — the §Perf lever that removes
the fusion-materialized softmax tiles from the LM memory term.

Single-(batch, head) program; batch/head dims are mapped with jax.vmap over
the pallas_call (vmap prepends grid dimensions).

  grid = (S/bq, T/bk): kv tiles iterate innermost (sequential), carrying the
  online-softmax state in VMEM scratch:
    m   [bq]      running row max
    l   [bq]      running denominator
    acc [bq, hd]  running numerator

  per step:  s = q_tile @ k_tile^T * scale + causal/window bias (iota mask)
             m' = max(m, rowmax(s)); p = exp(s - m'); corr = exp(m - m')
             l' = l*corr + rowsum(p); acc' = acc*corr + p @ v_tile
  emit at the last kv tile: out = acc / l.

Working set: bq*hd (q) + bk*hd (k) + bk*hd (v) + bq*bk (p) + scratch
≈ 4 * 128 * 128 * 4B tiles — VMEM-resident; HBM traffic is exactly
q + k + v + out, the flash optimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = float(-1e30)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_s, l_s, a_s,
    *, bq: int, bk: int, scale: float, q_offset: int, window,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full(m_s.shape, NEG_BIG, jnp.float32)
        l_s[...] = jnp.zeros(l_s.shape, jnp.float32)
        a_s[...] = jnp.zeros(a_s.shape, jnp.float32)

    q = q_ref[...]  # [bq, hd]
    k = k_ref[...]  # [bk, hd]
    v = v_ref[...]  # [bk, hd]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                                 # [bq, bk]

    q_idx = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_idx <= q_idx
    if window is not None:
        ok &= k_idx > q_idx - window
    s = jnp.where(ok, s, NEG_BIG)

    m_prev, l_prev, a_prev = m_s[...], l_s[...], a_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_s[...] = m_new
    l_s[...] = l_new
    a_s[...] = a_prev * corr[:, None] + pv

    @pl.when(j == nj - 1)
    def _emit():
        o_ref[...] = (
            a_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_head(
    q: jax.Array,       # [S, hd]
    k: jax.Array,       # [T, hd]
    v: jax.Array,       # [T, hd]
    *,
    q_offset: int = 0,
    window=None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    s, hd = q.shape
    t = k.shape[0]
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    kernel = functools.partial(
        _flash_kernel,
        bq=bq,
        bk=bk,
        scale=1.0 / (hd**0.5),
        q_offset=q_offset,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=(s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((s, hd), v.dtype),
        interpret=interpret,
    )(q, k, v)
