"""jit'd multi-head/batch wrapper: vmaps the single-head Pallas program over
batch and (kv-head x group) dims — the layout models/layers.py uses."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_head


@functools.partial(
    jax.jit, static_argnames=("q_offset", "window", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,   # [B, S, H, hd]
    k: jax.Array,   # [B, T, KV, hd]
    v: jax.Array,   # [B, T, KV, hd]
    *,
    q_offset: int = 0,
    window=None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)

    head = functools.partial(
        flash_attention_head,
        q_offset=q_offset, window=window, bq=min(bq, s), bk=min(bk, t),
        interpret=interpret,
    )
    # vmap nesting (outside-in): batch 0, kv-head 1, group 1; k/v broadcast
    # over the group dim
    f_g = jax.vmap(head, in_axes=(1, None, None), out_axes=1)   # [S,G,hd]
    f_kv = jax.vmap(f_g, in_axes=(1, 1, 1), out_axes=1)         # [S,KV,G,hd]
    f_b = jax.vmap(f_kv, in_axes=(0, 0, 0), out_axes=0)
    out = f_b(qg, k, v)  # [B, S, KV, G, hd]
    return out.reshape(b, s, h, hd)
