"""jit'd wrapper for beam_step: pads d to the 128 lane width, converts the
bool/int flag layouts, and exposes the beam_step_ref signature so
``core.search.beam_search`` can dispatch to it as a ``step_fn``.

Padding note: zero-padding the feature axis leaves fp32 inner products
bit-identical, so the wrapper is a drop-in even for odd d; callers on the hot
path (the walk loop) pre-pad queries/items once outside the ``while_loop`` so
the per-step pads here fold away to no-ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.beam_step.kernel import beam_step_pallas
from repro.kernels.beam_step.ref import StepResult


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def beam_step(
    pool_ids: jax.Array,      # [B, L] int32
    pool_scores: jax.Array,   # [B, L] fp32
    pool_checked: jax.Array,  # [B, L] bool
    visited: jax.Array,       # [B, V] int32
    done: jax.Array,          # [B] bool
    queries: jax.Array,       # [B, d]
    adj: jax.Array,           # [N, M] int32
    items: jax.Array,         # [N, d] fp32 items — or int8 codes (quantized)
    scales: "jax.Array | None" = None,  # [N] fp32 per-row scales (int8 store)
    live: "jax.Array | None" = None,    # [N] bool/int tombstone mask
    *,
    interpret: bool = True,
) -> StepResult:
    """Drop-in for beam_step_ref backed by the fused Pallas kernel.

    With ``scales`` given, ``items`` is the int8 store's code matrix and the
    step scores are the quantized convention ``(q . codes) * scale``
    (DESIGN.md §8).  Zero-padding the int8 code axis keeps the fp32 dot of
    the cast codes bit-identical, same as the fp32 rule above.

    With ``live`` given (the mutation layer's tombstone mask, DESIGN.md §9),
    ``n_dead`` counts this step's evaluations that landed on tombstones;
    pool contents are unchanged — dead nodes stay traversable.  Without it
    ``n_dead`` is None — matching beam_step_ref's contract (pinned in
    tests/test_kernel_parity.py) — even though the kernel still emits its
    (all-zero) dead-count output; the wrapper drops it."""
    d = queries.shape[-1]
    dp = _round_up(d, 128)
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, dp - d)))
    if scales is None:
        x = jnp.pad(items.astype(jnp.float32), ((0, 0), (0, dp - d)))
        scl = None
    else:
        x = jnp.pad(items.astype(jnp.int8), ((0, 0), (0, dp - d)))
        scl = scales.reshape(-1, 1).astype(jnp.float32)
    lv = None if live is None else live.reshape(-1, 1).astype(jnp.int32)
    oi, os, oc, onb, odn, onv, ond = beam_step_pallas(
        pool_ids.astype(jnp.int32),
        pool_scores.astype(jnp.float32),
        pool_checked.astype(jnp.int32),
        done.astype(jnp.int32)[:, None],
        visited.astype(jnp.int32),
        q,
        adj.astype(jnp.int32),
        x,
        scl,
        lv,
        interpret=interpret,
    )
    return StepResult(
        pool_ids=oi,
        pool_scores=os,
        pool_checked=oc != 0,
        nbr_ids=onb,
        done=odn[:, 0] != 0,
        n_scored=onv[:, 0],
        n_dead=None if live is None else ond[:, 0],
    )
