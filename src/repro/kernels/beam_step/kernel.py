"""Fused beam-step Pallas TPU kernel — one full Algorithm-1 iteration per
query in VMEM, no HBM round-trip between stages.

Composes the two existing building blocks into a single kernel:
  * gather_score's data-dependent row gather (here via explicit async DMA,
    because the gathered ids are *computed inside* the kernel from the pool
    state, so a scalar-prefetch BlockSpec cannot express them), and
  * topk_merge's L-pass masked-max selection network (``masked_top_l``).

Per grid step (one query):
  1. select the best unchecked pool slot (pool sorted desc => first unchecked)
     and mark it checked;
  2. DMA the adjacency row ``adj[cur]`` HBM->SMEM (scalar ids for the gather
     loop) and HBM->VMEM (vector lanes for the masks);
  3. DMA the M neighbor item rows HBM->VMEM — all started before any wait, so
     on TPU the fetches overlap;
  4. mask ids against the visited ring buffer, dot the rows with the query
     (MXU), and merge into the sorted pool — all without leaving VMEM.

Only the new pool state, the masked neighbor ids and two scalars per query go
back to HBM.  The XLA reference path materializes the gathered [B, M, d]
rows, the [B, M, V] dedup mask and the [B, L+M] merge candidates in HBM
between ~6 separate ops; here they live and die in registers/VMEM.

VMEM budget per query: M*dp*4 (gathered rows) + (L+V+3M) ints/floats —
~9 KB for M=16, dp=128, L=64, V=2k; far under the ~16 MB/core limit, so bb
could later tile many queries per step.

Ids must be valid graph state (pool ids >= -1, adjacency -1 padded); the
caller contract matches beam_step_ref bit-for-bit on result ids.

int8 storage (DESIGN.md §8): with ``scales`` given, ``items`` holds the
quantized store's codes — the row gather DMAs 1-byte elements (4x less HBM
per step), the cast to fp32 and the per-row rescale happen in VMEM, and the
dot accumulates fp32.  Ids remain bit-identical to the reference walking the
same store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.topk_merge.kernel import NEG_INF, masked_top_l


def _beam_step_kernel(
    pi_ref, ps_ref, pc_ref, dn_ref, vis_ref, q_ref,   # VMEM-blocked inputs
    adj_hbm, items_hbm,                               # whole arrays, ANY/HBM
    *rest,
    l: int,
    m: int,
    quantized: bool = False,
    has_live: bool = False,
):
    # The int8 storage backend (DESIGN.md §8) adds one HBM input (the [N, 1]
    # per-row dequant scales) and one VMEM scratch (the gathered scales);
    # ``items_hbm`` then holds the 1-byte codes and ``rows_ref`` is int8.
    # The mutation layer (DESIGN.md §9) adds the [N, 1] live column and its
    # gathered-bits scratch the same way — the two ride the identical
    # per-neighbor scalar-DMA pattern, so their layouts compose freely.
    rest = list(rest)
    scl_hbm = rest.pop(0) if quantized else None
    live_hbm = rest.pop(0) if has_live else None
    (oi_ref, os_ref, oc_ref, onb_ref, odn_ref, onv_ref, ond_ref,
     adj_smem, adj_vmem, rows_ref) = rest[:10]
    rest = rest[10:]
    scl_ref = rest.pop(0) if quantized else None
    live_ref = rest.pop(0) if has_live else None
    (sems,) = rest
    # Per-neighbor DMA semaphore bases: rows at 0..m-1, adjacency at m/m+1,
    # then one contiguous block per optional column in operand order.
    scl_base = m + 2
    live_base = m + 2 + (m if quantized else 0)
    pool_ids = pi_ref[...]                 # [1, L] int32
    pool_scores = ps_ref[...]              # [1, L] fp32
    pool_checked = pc_ref[...] != 0        # [1, L] bool

    # --- 1. select best unchecked slot --------------------------------------
    unchecked = (~pool_checked) & (pool_ids >= 0)
    done = (dn_ref[0, 0] != 0) | ~jnp.any(unchecked)
    upd = ~done
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (1, l), 1)
    cur_slot = jnp.min(jnp.where(unchecked, slot_iota, l))
    hit = unchecked & (slot_iota == cur_slot)
    cur = jnp.maximum(jnp.where(upd, jnp.max(jnp.where(hit, pool_ids, -1)), 0), 0)
    checked = pool_checked | (hit & upd)

    # Done queries skip all DMA: their neighbor results are fully masked by
    # ``upd`` below, so stale/uninitialized scratch contents are never
    # observable, and the walk stops streaming HBM for early finishers while
    # the batch waits on stragglers.
    @pl.when(upd)
    def _fetch():
        # --- 2. adjacency row: HBM -> SMEM (scalars) + VMEM (lanes) ---------
        adj_s = pltpu.make_async_copy(
            adj_hbm.at[pl.ds(cur, 1), :], adj_smem, sems.at[m]
        )
        adj_v = pltpu.make_async_copy(
            adj_hbm.at[pl.ds(cur, 1), :], adj_vmem, sems.at[m + 1]
        )
        adj_s.start()
        adj_v.start()
        adj_s.wait()
        adj_v.wait()

        # --- 3. gather the M neighbor rows (start all, then wait all) -------
        # Quantized rows are 1-byte — the DMA streams d bytes per neighbor
        # instead of 4d; the matching [1, 1] scale element rides along from
        # the scales column so the rescale never leaves VMEM.
        def _row_copy(j):
            nid = jnp.maximum(adj_smem[0, j], 0)
            return pltpu.make_async_copy(
                items_hbm.at[pl.ds(nid, 1), :], rows_ref.at[pl.ds(j, 1), :],
                sems.at[j],
            )

        def _scl_copy(j):
            nid = jnp.maximum(adj_smem[0, j], 0)
            return pltpu.make_async_copy(
                scl_hbm.at[pl.ds(nid, 1), :], scl_ref.at[:, pl.ds(j, 1)],
                sems.at[scl_base + j],
            )

        def _live_copy(j):
            nid = jnp.maximum(adj_smem[0, j], 0)
            return pltpu.make_async_copy(
                live_hbm.at[pl.ds(nid, 1), :], live_ref.at[:, pl.ds(j, 1)],
                sems.at[live_base + j],
            )

        jax.lax.fori_loop(0, m, lambda j, c: (_row_copy(j).start(), c)[1], 0)
        if quantized:
            jax.lax.fori_loop(0, m, lambda j, c: (_scl_copy(j).start(), c)[1], 0)
        if has_live:
            jax.lax.fori_loop(0, m, lambda j, c: (_live_copy(j).start(), c)[1], 0)
        jax.lax.fori_loop(0, m, lambda j, c: (_row_copy(j).wait(), c)[1], 0)
        if quantized:
            jax.lax.fori_loop(0, m, lambda j, c: (_scl_copy(j).wait(), c)[1], 0)
        if has_live:
            jax.lax.fori_loop(0, m, lambda j, c: (_live_copy(j).wait(), c)[1], 0)

    # --- 4. dedup-mask, score, merge — all in VMEM --------------------------
    nbrs = adj_vmem[...]                   # [1, M] int32
    seen = (nbrs[:, :, None] == vis_ref[...][:, None, :]).any(axis=-1)
    valid = (nbrs >= 0) & upd & ~seen

    rows = rows_ref[...]
    if quantized:
        rows = rows.astype(jnp.float32)    # cast codes in VMEM, never in HBM
    scores = jax.lax.dot_general(
        q_ref[...], rows,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # [1, M]
    if quantized:
        # One multiply per score — the ref.py/quant_score op-order contract.
        scores = scores * scl_ref[...]
    nbr_scores = jnp.where(valid, scores, NEG_INF)
    nbr_ids = jnp.where(valid, nbrs, -1)

    cand_s = jnp.concatenate([pool_scores, nbr_scores], axis=1)
    cand_i = jnp.concatenate([pool_ids, nbr_ids], axis=1)
    cand_c = jnp.concatenate(
        [checked.astype(jnp.int32), (~valid).astype(jnp.int32)], axis=1
    )
    out_s, out_i, out_c = masked_top_l(cand_s, cand_i, cand_c, l)

    os_ref[...] = out_s
    oi_ref[...] = out_i
    oc_ref[...] = out_c
    onb_ref[...] = nbr_ids
    odn_ref[0, 0] = done.astype(jnp.int32)
    onv_ref[0, 0] = jnp.sum(valid.astype(jnp.int32))
    if has_live:
        # Tombstoned evaluations: valid neighbors whose live bit is 0.  Like
        # the scales, live bits of done queries are uninitialized scratch —
        # masked out because ``valid`` is all-False when ``upd`` is.
        dead = valid & (live_ref[...] == 0)
        ond_ref[0, 0] = jnp.sum(dead.astype(jnp.int32))
    else:
        ond_ref[0, 0] = jnp.int32(0)


def beam_step_pallas(
    pool_ids: jax.Array,      # [B, L] int32
    pool_scores: jax.Array,   # [B, L] fp32
    pool_checked: jax.Array,  # [B, L] int32 0/1
    done: jax.Array,          # [B, 1] int32 0/1
    visited: jax.Array,       # [B, V] int32 (-1 padded)
    queries: jax.Array,       # [B, dp] fp32, dp a lane multiple
    adj: jax.Array,           # [N, M] int32 (-1 padded)
    items: jax.Array,         # [N, dp] fp32 items — or int8 codes (quantized)
    scales: "jax.Array | None" = None,  # [N, 1] fp32 dequant scales (int8)
    live: "jax.Array | None" = None,    # [N, 1] int32 0/1 tombstone mask
    *,
    interpret: bool = True,
):
    """One fused Algorithm-1 iteration for every query.  Returns
    (pool_ids, pool_scores, pool_checked, nbr_ids, done, n_scored, n_dead)
    with the pool sorted desc and ids bit-identical to beam_step_ref.

    With ``scales`` given, ``items`` holds the int8 store's codes: neighbor
    rows DMA as 1-byte elements and scores are ``(q . codes) * scale``
    (DESIGN.md §8) — bit-identical to ``beam_step_ref`` walking the same
    store through ``quant_score_ref``.

    With ``live`` given (core/mutation.py's tombstone column), neighbor live
    bits ride the same per-neighbor scalar DMA and ``n_dead`` counts the
    evaluations spent on tombstones; scores/merges are unchanged — dead nodes
    stay traversable and are filtered from results by the caller.  Without it
    ``n_dead`` is all zeros."""
    b, l = pool_ids.shape
    v = visited.shape[1]
    dp = queries.shape[1]
    m = adj.shape[1]
    quantized = scales is not None
    has_live = live is not None

    spec_l = pl.BlockSpec((1, l), lambda i: (i, 0))
    spec_1 = pl.BlockSpec((1, 1), lambda i: (i, 0))
    spec_any = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    in_specs = [
        spec_l,                                   # pool_ids
        spec_l,                                   # pool_scores
        spec_l,                                   # pool_checked
        spec_1,                                   # done
        pl.BlockSpec((1, v), lambda i: (i, 0)),   # visited
        pl.BlockSpec((1, dp), lambda i: (i, 0)),  # query
        spec_any,                                 # adj (HBM)
        spec_any,                                 # items / codes (HBM)
    ]
    operands = [pool_ids, pool_scores, pool_checked, done, visited, queries,
                adj, items]
    scratch = [
        pltpu.SMEM((1, m), jnp.int32),
        pltpu.VMEM((1, m), jnp.int32),
        pltpu.VMEM((m, dp), items.dtype),         # int8 rows when quantized
    ]
    if quantized:
        in_specs.append(spec_any)                 # scales column (HBM)
        operands.append(scales)
        scratch.append(pltpu.VMEM((1, m), jnp.float32))   # gathered scales
    if has_live:
        in_specs.append(spec_any)                 # live column (HBM)
        operands.append(live)
        scratch.append(pltpu.VMEM((1, m), jnp.int32))     # gathered live bits
    n_sems = m + 2 + (m if quantized else 0) + (m if has_live else 0)
    scratch.append(pltpu.SemaphoreType.DMA((n_sems,)))

    return pl.pallas_call(
        functools.partial(_beam_step_kernel, l=l, m=m, quantized=quantized,
                          has_live=has_live),
        grid=(b,),
        in_specs=in_specs,
        out_specs=(
            spec_l, spec_l, spec_l,
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            spec_1, spec_1, spec_1,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, l), jnp.int32),
            jax.ShapeDtypeStruct((b, l), jnp.float32),
            jax.ShapeDtypeStruct((b, l), jnp.int32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
