from repro.kernels.beam_step.ops import beam_step
from repro.kernels.beam_step.ref import StepResult, beam_step_ref

__all__ = ["StepResult", "beam_step", "beam_step_ref"]
