"""Pure-jnp oracle for beam_step — one Algorithm-1 iteration, extracted
verbatim from the original ``core.search.beam_search`` loop body.

This IS the reference backend of ``beam_search``: the walk loop calls it
through the ``step_fn`` dispatch, so the oracle and the production reference
path cannot drift apart.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.similarity import gather_scores

# Plain Python float, not jnp.float32: this module is imported lazily from
# inside jit traces (search.make_step_fn), where creating a jax value at
# module scope would leak a tracer.
NEG_INF = float("-inf")


class StepResult(NamedTuple):
    """State delta of one walk iteration (visited/evals updates are applied
    by the caller, which owns the ring-buffer offset)."""

    pool_ids: jax.Array      # [B, L] int32, sorted desc by score
    pool_scores: jax.Array   # [B, L] fp32
    pool_checked: jax.Array  # [B, L] bool
    nbr_ids: jax.Array       # [B, M] int32 newly-scored ids (-1 masked)
    done: jax.Array          # [B] bool (sticky)
    n_scored: jax.Array      # [B] int32 similarity evaluations this step
    n_dead: Optional[jax.Array] = None  # [B] int32 tombstoned evaluations
    #   (None when the walk carries no live mask — mutation off)


def beam_step_ref(
    pool_ids: jax.Array,
    pool_scores: jax.Array,
    pool_checked: jax.Array,
    visited: jax.Array,
    done: jax.Array,
    queries: jax.Array,
    adj: jax.Array,
    items: jax.Array,
    *,
    score_fn=gather_scores,
    live: Optional[jax.Array] = None,
) -> StepResult:
    """Select the best unchecked pool slot, expand its adjacency row, mask
    visited/invalid neighbors, score the rest, and merge into the pool.

    ``live`` ([N] bool, core/mutation.py's tombstone mask) does NOT change
    which neighbors are scored or merged — dead nodes stay traversable
    routing vertices (they are the large-norm highways of the paper's §4
    hub analysis, and cutting them would sever navigability exactly when
    churn hits hardest).  The mask's only effect here is the ``n_dead``
    count: evaluations spent on tombstones, the churn-health signal
    ``beam_search`` accumulates into ``SearchResult.dead_evals``.  Dead
    nodes are excluded from RESULTS at the final cut in ``beam_search``."""
    B, L = pool_ids.shape
    rows = jnp.arange(B)

    unchecked = (~pool_checked) & (pool_ids >= 0)
    has_unchecked = unchecked.any(axis=-1)
    new_done = done | ~has_unchecked
    upd = ~new_done  # queries that take a step this iteration

    # Pool is sorted desc => first unchecked slot is the best unchecked.
    cur_slot = jnp.argmax(unchecked, axis=-1)
    cur_id = pool_ids[rows, cur_slot]
    cur_id = jnp.maximum(jnp.where(upd, cur_id, 0), 0)

    checked = pool_checked | (
        jax.nn.one_hot(cur_slot, L, dtype=bool) & upd[:, None]
    )

    nbrs = adj[cur_id]  # [B, M]
    valid = (nbrs >= 0) & upd[:, None]
    seen = (nbrs[:, :, None] == visited[:, None, :]).any(axis=-1)
    valid &= ~seen

    nbr_scores = score_fn(queries, items, nbrs)
    nbr_scores = jnp.where(valid, nbr_scores, NEG_INF)
    nbr_ids = jnp.where(valid, nbrs, -1).astype(jnp.int32)
    n_scored = valid.sum(axis=-1).astype(jnp.int32)
    # Contract (pinned in tests/test_kernel_parity.py): n_dead is None —
    # not a zeros array — whenever the walk carries no live mask, on BOTH
    # step backends, so callers can distinguish "mutation off" from "no
    # tombstones hit" without inspecting values.
    if live is None:
        n_dead = None
    else:
        dead = valid & ~live.astype(bool)[jnp.maximum(nbrs, 0)]
        n_dead = dead.sum(axis=-1).astype(jnp.int32)

    cand_ids = jnp.concatenate([pool_ids, nbr_ids], axis=-1)
    cand_scores = jnp.concatenate([pool_scores, nbr_scores], axis=-1)
    cand_checked = jnp.concatenate([checked, ~valid], axis=-1)

    new_scores, sel = jax.lax.top_k(cand_scores, L)
    new_ids = jnp.take_along_axis(cand_ids, sel, axis=-1)
    new_checked = jnp.take_along_axis(cand_checked, sel, axis=-1)

    return StepResult(
        pool_ids=new_ids,
        pool_scores=new_scores,
        pool_checked=new_checked,
        nbr_ids=nbr_ids,
        done=new_done,
        n_scored=n_scored,
        n_dead=n_dead,
    )
