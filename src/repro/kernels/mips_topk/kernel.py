"""Tiled exact-MIPS + streaming top-k Pallas TPU kernel.

The `retrieval_cand` hot path (1 query batch x 10^6 candidates) and the
paper's linear-scan baseline.  Design (TPU-native, see DESIGN.md §6):

  grid = (B/bq, N/bn); the item axis is the inner (sequential) dimension so
  the [bq, k] top-k accumulator lives in VMEM scratch across item tiles.

  per step:   scores = q_tile @ x_tile^T           (MXU, fp32 accumulation)
              acc    = top_k(concat(acc, scores))   (k-pass VPU selection —
                       no sort/gather primitives, TPU-lowerable)

  HBM traffic: each item row is read exactly ONCE (N*d*4 bytes) regardless of
  the query count — the kernel is item-bandwidth-bound by construction, which
  is the roofline optimum for N >> B.

The k-pass selection extracts the max k times with iota-masking; id selection
uses a masked max instead of take_along_axis (no dynamic gather on TPU VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _select_topk(cand_s, cand_i, k: int):
    """Top-k of each row of (cand_s, cand_i) by score — k unrolled max-passes.
    cand_s: [bq, L] fp32, cand_i: [bq, L] int32 -> ([bq, k], [bq, k])."""
    out_s, out_i = [], []
    col = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1)
    for _ in range(k):
        m = jnp.max(cand_s, axis=1)                        # [bq]
        amax = jnp.argmax(cand_s, axis=1)                  # first max position
        hit = col == amax[:, None]
        sel = jnp.max(jnp.where(hit, cand_i, -1), axis=1)  # masked-max gather
        out_s.append(m)
        out_i.append(sel)
        cand_s = jnp.where(hit, NEG_INF, cand_s)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _mips_topk_kernel(
    q_ref, x_ref, *rest, k: int, bn: int, n_items: int, quantized: bool = False
):
    # int8 storage (DESIGN.md §8): the item tile arrives as 1-byte codes plus
    # a [1, bn] scale row; the cast and the per-row rescale stay in VMEM and
    # the streamed HBM bytes drop ~4x.
    if quantized:
        scl_ref, out_s_ref, out_i_ref, acc_s, acc_i = rest
    else:
        scl_ref = None
        out_s_ref, out_i_ref, acc_s, acc_i = rest
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full(acc_s.shape, NEG_INF, jnp.float32)
        acc_i[...] = jnp.full(acc_i.shape, -1, jnp.int32)

    q = q_ref[...]  # [bq, d]
    x = x_ref[...]  # [bn, d]
    if quantized:
        x = x.astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bn]
    if quantized:
        scores = scores * scl_ref[...]  # [1, bn] broadcast over queries
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < n_items, scores, NEG_INF)  # mask ragged tail

    cand_s = jnp.concatenate([acc_s[...], scores], axis=1)
    cand_i = jnp.concatenate([acc_i[...], cols], axis=1)
    new_s, new_i = _select_topk(cand_s, cand_i, k)
    acc_s[...] = new_s
    acc_i[...] = new_i

    @pl.when(j == nj - 1)
    def _emit():
        out_s_ref[...] = acc_s[...]
        out_i_ref[...] = acc_i[...]


def mips_topk_pallas(
    queries: jax.Array,
    items: jax.Array,
    scales: "jax.Array | None" = None,
    *,
    k: int,
    bq: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    """queries [B, d], items [N, d] (both pre-padded: B%bq==0, N%bn==0,
    d%128==0) -> (scores [B, k], ids [B, k]).  ``n_items`` masking of padded
    item rows is applied inside the kernel via the true N passed by ops.py.

    With ``scales`` ([1, N] fp32, pre-padded like the item rows), ``items``
    holds int8 codes and scores follow the quantized convention
    ``(q . codes) * scale`` (DESIGN.md §8)."""
    b, d = queries.shape
    n = items.shape[0]
    assert b % bq == 0 and n % bn == 0, (b, bq, n, bn)
    quantized = scales is not None

    grid = (b // bq, n // bn)
    kernel = functools.partial(
        _mips_topk_kernel, k=k, bn=bn, n_items=n, quantized=quantized
    )
    in_specs = [
        pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
    ]
    operands = [queries, items]
    if quantized:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        operands.append(scales)
    out_shape = (
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
