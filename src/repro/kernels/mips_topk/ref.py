"""Pure-jnp oracle for the mips_topk kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mips_topk_ref(queries: jax.Array, items: jax.Array, *, k: int):
    scores = jnp.einsum(
        "bd,nd->bn", queries, items, preferred_element_type=jnp.float32
    )
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids.astype(jnp.int32)
