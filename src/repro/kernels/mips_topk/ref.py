"""Pure-jnp oracle for the mips_topk kernel.

With ``scales`` given, ``items`` holds int8 codes and the oracle follows the
quantized-score convention ``(q . codes) * scale`` (DESIGN.md §8) — the same
op order the kernel's tile path uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mips_topk_ref(
    queries: jax.Array,
    items: jax.Array,
    *,
    k: int,
    scales: "jax.Array | None" = None,
):
    scores = jnp.einsum(
        "bd,nd->bn",
        queries.astype(jnp.float32),
        items.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if scales is not None:
        scores = scores * scales[None, :]
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids.astype(jnp.int32)
