"""jit'd public wrapper for mips_topk: pads (B, N, d) to tile multiples,
masks padded item rows, strips query padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mips_topk.kernel import _mips_topk_kernel, NEG_INF
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit, static_argnames=("k", "bq", "bn", "interpret")
)
def mips_topk(
    queries: jax.Array,
    items: jax.Array,
    scales: "jax.Array | None" = None,
    *,
    k: int = 10,
    bq: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    """Exact top-k MIPS.  queries [B, d], items [N, d] (any shapes).

    With ``scales`` ([N] fp32), ``items`` holds the int8 store's codes and
    the scan scores are the quantized convention ``(q . codes) * scale``
    (DESIGN.md §8) — the tile streams 1-byte rows instead of fp32."""
    b, d = queries.shape
    n = items.shape[0]
    bq = min(bq, _round_up(b, 8))
    bn = min(bn, _round_up(n, 128))

    bp, np_, dp = _round_up(b, bq), _round_up(n, bn), _round_up(d, 128)
    q = jnp.pad(queries.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    if scales is None:
        x = jnp.pad(items.astype(jnp.float32), ((0, np_ - n), (0, dp - d)))
        scl = None
    else:
        x = jnp.pad(items.astype(jnp.int8), ((0, np_ - n), (0, dp - d)))
        scl = jnp.pad(scales.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)
    grid = (bp // bq, np_ // bn)
    kernel = functools.partial(
        _mips_topk_kernel, k=k, bn=bn, n_items=n, quantized=scl is not None
    )
    in_specs = [
        pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
    ]
    operands = [q, x]
    if scl is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        operands.append(scl)
    scores, ids = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
        ),
        interpret=interpret,
    )(*operands)
    return scores[:b], ids[:b]
