"""Pallas TPU kernels for the MIPS hot spots (validated with interpret=True
on CPU; TPU is the compile target).

  mips_topk    — tiled exact-MIPS linear scan + streaming top-k (MXU)
  gather_score — scalar-prefetch fused row-gather + dot (beam expansion)
  topk_merge   — in-VMEM candidate-pool merge (Algorithm 1 line 7-8)
  beam_step    — fused full Algorithm-1 iteration (select + gather + dedup +
                 score + merge in VMEM); the "pallas" walk backend (DESIGN §3)
  commit_merge — fused reverse-link top-M merge of the Algorithm-2 batched
                 commit (bucket + gather + rescore + dedup + rank per target
                 tile in VMEM); the "pallas" commit backend (DESIGN §7)
  quant_score  — fused int8 row-gather + dequant + dot (1-byte DMA, fp32
                 rescale in VMEM); the gathered scorer of the "int8"
                 storage backend (DESIGN §8)
"""
