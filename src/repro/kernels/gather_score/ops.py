"""jit'd wrapper for gather_score: pads d to the 128 lane width, clamps ids,
and exposes the similarity.gather_scores signature (so beam_search can take
it as ``score_fn``)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_score.kernel import gather_score_pallas


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_score(
    queries: jax.Array,
    items: jax.Array,
    ids: jax.Array,
    *,
    interpret: bool = True,
):
    """Drop-in for similarity.gather_scores: ids may contain -1 (scored
    against row 0; caller masks)."""
    d = queries.shape[-1]
    dp = _round_up(d, 128)
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, dp - d)))
    x = jnp.pad(items.astype(jnp.float32), ((0, 0), (0, dp - d)))
    safe = jnp.maximum(ids, 0).astype(jnp.int32)
    return gather_score_pallas(q, x, safe, interpret=interpret)
