"""Fused gather + dot Pallas TPU kernel — the beam-expansion hot loop.

Replaces the CPU pointer-chase "for each neighbor v: compute q.v" with a
scalar-prefetch gather: neighbor ids are prefetched into SMEM, and the item
BlockSpec's index_map uses them to DMA exactly the needed rows HBM->VMEM,
fused with the per-query dot product.  No [B*W, d] gather ever materializes
in HBM.

grid = (B, W/bw): step (b, w) gathers ``bw`` neighbor rows of query b.
Because consecutive walk steps revisit high-in-degree (large-norm) hub items
(paper Fig 4/5), the same rows are fetched repeatedly — on TPU these hit the
VMEM-resident DMA window, which is exactly how the norm bias of the walk
turns into cache locality.  Ids must be pre-clamped to [0, N); masking of
invalid slots is the caller's contract (same as similarity.gather_scores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_score_kernel(ids_ref, q_ref, x_ref, o_ref, *, bw: int):
    # q_ref: [1, d]; x_ref: [bw, d] — rows gathered one block per grid step
    # via the index_map below; o_ref: [1, bw].
    q = q_ref[0, :]
    x = x_ref[...]
    o_ref[0, :] = jnp.sum(x * q[None, :], axis=1, dtype=jnp.float32)


def _gather_score_kernel_rowwise(ids_ref, q_ref, x_ref, o_ref):
    # One gathered row per grid step: q [1, d], x [1, d] -> o [1, 1].
    o_ref[0, 0] = jnp.sum(q_ref[0, :] * x_ref[0, :], dtype=jnp.float32)


def gather_score_pallas(
    queries: jax.Array,
    items: jax.Array,
    ids: jax.Array,
    *,
    interpret: bool = True,
):
    """queries [B, d], items [N, d], ids [B, W] int32 in [0, N) ->
    scores [B, W] fp32 where scores[b, w] = queries[b] . items[ids[b, w]]."""
    b, d = queries.shape
    w = ids.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, w),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
    )
    return pl.pallas_call(
        _gather_score_kernel_rowwise,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.float32),
        interpret=interpret,
    )(ids, queries, items)
