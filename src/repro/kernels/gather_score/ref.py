"""Pure-jnp oracle for gather_score (mirrors similarity.gather_scores with
pre-clamped ids)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_score_ref(queries: jax.Array, items: jax.Array, ids: jax.Array):
    vecs = items[ids]  # [B, W, d]
    return jnp.einsum(
        "bd,bwd->bw", queries, vecs, preferred_element_type=jnp.float32
    )
