from repro.kernels.gather_score.ops import gather_score
from repro.kernels.gather_score.ref import gather_score_ref

__all__ = ["gather_score", "gather_score_ref"]
