from repro.kernels.commit_merge.ops import commit_merge
from repro.kernels.commit_merge.ref import commit_merge_ref

__all__ = ["commit_merge", "commit_merge_ref"]
