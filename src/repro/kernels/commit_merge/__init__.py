from repro.kernels.commit_merge.ops import (
    DEFAULT_COMMIT_TILE,
    commit_merge,
    resolve_commit_tile,
)
from repro.kernels.commit_merge.ref import commit_merge_ref

__all__ = [
    "DEFAULT_COMMIT_TILE",
    "commit_merge",
    "commit_merge_ref",
    "resolve_commit_tile",
]
