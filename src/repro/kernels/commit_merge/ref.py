"""Pure-jnp oracle for commit_merge — the reverse-link segmented top-M merge
of the batched Algorithm-2 commit, moved verbatim from
``core.build._segmented_topM_merge``.

This IS the reference backend of ``core.build.commit_batch``: the commit
dispatch calls it directly, so the oracle and the production reference path
cannot drift apart (same contract as ``kernels/beam_step/ref.py``).

The oracle is deliberately UNtiled: it has no grid, no buckets and no
``commit_tile`` knob — its two device-wide sorts define the semantics every
(tile, backend) combination of the fused path must reproduce bit-for-bit,
so the tiling geometry can never leak into the contract it is tested
against (DESIGN.md §7).

Semantics (what any commit backend must reproduce bit-for-bit):
  * every edge ``(targets[i], cands[i], scores[i])`` proposes ``cands[i]`` as
    a reverse neighbor of ``targets[i]``; entries with ``targets[i] < 0`` are
    padding and propose nothing;
  * every row whose target appears with ``targets[i] >= 0`` — even when all
    of its proposed cands are ``-1`` — is fully rewritten: its existing edges
    are *rescored* (inner product against the target's vector) and re-ranked
    together with the proposals;
  * duplicate ``(target, cand)`` pairs collapse to the first proposal in
    input order; a proposal that duplicates an existing edge replaces it
    (the proposal's score wins);
  * each rewritten row keeps its top-M by score; ties resolve by ascending
    cand id (the stable (target, cand) pre-sort order); trailing slots are
    ``-1``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def commit_merge_ref(
    adj: jax.Array,
    items: jax.Array,
    targets: jax.Array,   # [E] int32 reverse-edge targets (-1 invalid)
    cands: jax.Array,     # [E] int32 candidate neighbors (the new items)
    scores: jax.Array,    # [E] fp32 s(target, cand)
) -> jax.Array:
    """Merge reverse-edge candidates into the adjacency rows of ``targets``,
    keeping each row's top-M by similarity.  Fully vectorized."""
    n, m = adj.shape
    e = targets.shape[0]
    big = jnp.int32(n + 1)

    # --- existing edges of touched targets (contributed once per target) ----
    order = jnp.argsort(jnp.where(targets >= 0, targets, big))
    t_s = targets[order]
    c_s = cands[order]
    s_s = scores[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), t_s[1:] != t_s[:-1]]
    ) & (t_s >= 0)

    safe_t = jnp.maximum(t_s, 0)
    ex_ids = adj[safe_t]                                   # [E, M]
    ex_valid = (ex_ids >= 0) & first[:, None]
    ex_vecs = items[jnp.maximum(ex_ids, 0)]                # [E, M, d]
    t_vecs = items[safe_t]                                 # [E, d]
    ex_scores = jnp.einsum(
        "ed,emd->em", t_vecs, ex_vecs, preferred_element_type=jnp.float32
    )

    # --- edge table ---------------------------------------------------------
    tab_t = jnp.concatenate([t_s, jnp.broadcast_to(t_s[:, None], (e, m)).reshape(-1)])
    tab_c = jnp.concatenate([c_s, ex_ids.reshape(-1)])
    tab_s = jnp.concatenate([s_s, ex_scores.reshape(-1)])
    tab_v = jnp.concatenate([t_s >= 0, ex_valid.reshape(-1)])
    tab_v &= tab_c >= 0

    # --- pass 1: drop duplicate (target, neighbor) pairs --------------------
    k1 = jnp.where(tab_v, tab_t, big)
    k2 = jnp.where(tab_v, tab_c, big)
    k1, k2, tab_t, tab_c, tab_s, tab_v = jax.lax.sort(
        (k1, k2, tab_t, tab_c, tab_s, tab_v), num_keys=2, is_stable=True
    )
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (k1[1:] == k1[:-1]) & (k2[1:] == k2[:-1])]
    )
    tab_v &= ~dup

    # --- pass 2: rank by score within each target segment -------------------
    k1 = jnp.where(tab_v, tab_t, big)
    nk = jnp.where(tab_v, -tab_s, jnp.float32(jnp.inf))
    k1, nk, tab_t, tab_c, tab_v = jax.lax.sort(
        (k1, nk, tab_t, tab_c, tab_v), num_keys=2, is_stable=True
    )
    r = tab_t.shape[0]
    idx = jnp.arange(r, dtype=jnp.int32)
    seg_first = jnp.concatenate([jnp.ones((1,), bool), k1[1:] != k1[:-1]])
    seg_start = jax.lax.cummax(jnp.where(seg_first, idx, 0))
    rank = idx - seg_start
    keep = tab_v & (rank < m)

    # --- scatter rows back (touched rows fully rewritten) --------------------
    adj_pad = jnp.concatenate([adj, jnp.full((1, m), -1, adj.dtype)], axis=0)
    row = jnp.where(first, safe_t, n)
    adj_pad = adj_pad.at[row].set(-1)  # clear touched rows (dummy row n absorbs)
    wr = jnp.where(keep, tab_t, n)
    wc = jnp.where(keep, rank, 0)
    adj_pad = adj_pad.at[wr, wc].set(jnp.where(keep, tab_c, -1))
    return adj_pad[:n]
