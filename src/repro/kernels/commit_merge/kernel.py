"""Fused commit-merge Pallas TPU kernel — the reverse-link top-M merge of the
batched Algorithm-2 commit, one target row per grid step, entirely in VMEM.

The reference path (``commit_merge_ref``) builds an ``E·(M+1)``-row edge
table (every proposal plus every existing edge of every touched target) and
pushes it through TWO device-wide ``lax.sort`` passes, materializing the
``[E, M, d]`` gathered neighbor vectors and the full table in HBM between
stages.  Here the wrapper (``ops.py``) buckets only the ``E`` proposals to
target tiles with ONE E-row sort, and each grid step finishes one touched
row on-chip:

  1. DMA the target's adjacency row HBM->SMEM (scalar ids for the gather
     loop) and HBM->VMEM (vector lanes), and the target's item vector
     HBM->VMEM;
  2. DMA the M existing-neighbor item rows HBM->VMEM — all copies started
     before any wait, so on TPU the fetches overlap (same explicit-DMA idiom
     as ``beam_step``: the ids are read from the row *inside* the kernel, so
     a scalar-prefetch BlockSpec cannot express them);
  3. rescore the existing edges against the target vector (MXU), drop
     existing slots that duplicate a proposal (the proposal's score wins)
     or an earlier existing slot;
  4. rank proposals + surviving existing edges with the ``ranked_top_m``
     selection network and write the row's new top-M ids.

Only the final ``[1, M]`` id row returns to HBM per step.  Pad steps
(``target < 0`` — the bucket table is sized for the worst case of all-unique
targets) skip every DMA and emit an all ``-1`` row that the wrapper scatters
into a dummy row.

VMEM budget per step: (M+1)·dp·4 (target + neighbor rows) + (2K + 3M) words
— ~12 KB for M=16, dp=128, K=512; far under the ~16 MB/core limit, so a
later revision could tile many targets per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def ranked_top_m(ids, scores, valid, m: int):
    """Top-``m`` of ``[B, C]`` candidates by (score desc, id asc), honoring an
    explicit ``valid`` mask.  Returns ``[B, m]`` int32 ids, ``-1`` padded.

    Differs from ``topk_merge.masked_top_l`` in two contract points that the
    commit merge needs: ties resolve by *smallest id* (the reference's stable
    rank over the (target, cand)-sorted table), not by slot position, and a
    valid slot may carry ``-inf`` and still outrank emptiness (the reference
    keeps valid ``-inf``-score edges when the row has spare capacity).
    Requires ids unique among valid slots — one hit per pass, like the
    reference's deduped table.  Statically unrolled compare/select trees.
    """
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    avail = valid
    out = []
    for _ in range(m):
        has = jnp.any(avail, axis=1)
        mx = jnp.max(jnp.where(avail, scores, NEG_INF), axis=1)
        tied = avail & (scores == mx[:, None])
        cmin = jnp.min(jnp.where(tied, ids, big), axis=1)
        hit = tied & (ids == cmin[:, None])
        out.append(jnp.where(has, cmin, -1))
        avail &= ~hit
    return jnp.stack(out, axis=1).astype(jnp.int32)


def _commit_merge_kernel(
    tgt_ref, bi_ref, bs_ref,          # VMEM-blocked inputs (one target tile)
    adj_hbm, items_hbm,               # whole arrays, ANY/HBM
    out_ref,                          # [1, M] new row ids
    adj_smem, adj_vmem, tvec_ref, rows_ref, sems,
    *,
    m: int,
):
    t = tgt_ref[0, 0]
    live = t >= 0
    tsafe = jnp.maximum(t, 0)

    # Pad steps skip all DMA: their outputs are fully masked by ``live``
    # below, so stale/uninitialized scratch contents are never observable.
    @pl.when(live)
    def _fetch():
        # --- 1. adjacency row (SMEM scalars + VMEM lanes) + target vector ---
        adj_s = pltpu.make_async_copy(
            adj_hbm.at[pl.ds(tsafe, 1), :], adj_smem, sems.at[m]
        )
        adj_v = pltpu.make_async_copy(
            adj_hbm.at[pl.ds(tsafe, 1), :], adj_vmem, sems.at[m + 1]
        )
        tv = pltpu.make_async_copy(
            items_hbm.at[pl.ds(tsafe, 1), :], tvec_ref, sems.at[m + 2]
        )
        adj_s.start()
        adj_v.start()
        tv.start()
        adj_s.wait()
        adj_v.wait()

        # --- 2. gather the M existing-neighbor rows (start all, wait all) ---
        def _row_copy(j):
            nid = jnp.maximum(adj_smem[0, j], 0)
            return pltpu.make_async_copy(
                items_hbm.at[pl.ds(nid, 1), :], rows_ref.at[pl.ds(j, 1), :],
                sems.at[j],
            )

        jax.lax.fori_loop(0, m, lambda j, c: (_row_copy(j).start(), c)[1], 0)
        jax.lax.fori_loop(0, m, lambda j, c: (_row_copy(j).wait(), c)[1], 0)
        tv.wait()

    # --- 3. dedup + rescore — all in VMEM -----------------------------------
    new_ids = bi_ref[...]                             # [1, K] (-1 padded)
    new_valid = (new_ids >= 0) & live
    new_scores = jnp.where(new_valid, bs_ref[...], NEG_INF)

    ex_ids = adj_vmem[...]                            # [1, M]
    # existing slot duplicated by a proposal -> dropped (proposal score wins)
    in_new = (
        (ex_ids[:, :, None] == new_ids[:, None, :]) & new_valid[:, None, :]
    ).any(axis=-1)
    # existing slot repeating an earlier existing slot -> dropped (keep first)
    eq = ex_ids[:, :, None] == ex_ids[:, None, :]
    jj = jax.lax.broadcasted_iota(jnp.int32, (1, m, m), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (1, m, m), 2)
    ex_dup = (eq & (kk < jj)).any(axis=-1)
    ex_valid = (ex_ids >= 0) & live & ~in_new & ~ex_dup

    ex_scores = jax.lax.dot_general(
        tvec_ref[...], rows_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [1, M]
    ex_scores = jnp.where(ex_valid, ex_scores, NEG_INF)

    # --- 4. rank and rewrite the row ----------------------------------------
    cand_i = jnp.concatenate(
        [jnp.where(new_valid, new_ids, -1), jnp.where(ex_valid, ex_ids, -1)],
        axis=1,
    )
    cand_s = jnp.concatenate([new_scores, ex_scores], axis=1)
    cand_v = jnp.concatenate([new_valid, ex_valid], axis=1)
    out_ref[...] = ranked_top_m(cand_i, cand_s, cand_v, m)


def commit_merge_pallas(
    utgt: jax.Array,          # [G, 1] int32 unique targets (-1 pad steps)
    bucket_ids: jax.Array,    # [G, K] int32 deduped proposal ids (-1 padded)
    bucket_scores: jax.Array, # [G, K] fp32 proposal scores
    adj: jax.Array,           # [N, M] int32 (-1 padded)
    items: jax.Array,         # [N, dp] fp32, dp a lane multiple
    *,
    interpret: bool = True,
):
    """One fused reverse-link merge step per unique target.  Returns the
    ``[G, M]`` rewritten row ids (all ``-1`` for pad steps); the wrapper owns
    the bucketing pre-pass and the row scatter."""
    g = utgt.shape[0]
    k = bucket_ids.shape[1]
    m = adj.shape[1]
    dp = items.shape[1]

    spec_any = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    return pl.pallas_call(
        functools.partial(_commit_merge_kernel, m=m),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),   # target id
            pl.BlockSpec((1, k), lambda i: (i, 0)),   # proposal ids
            pl.BlockSpec((1, k), lambda i: (i, 0)),   # proposal scores
            spec_any,                                 # adj (HBM)
            spec_any,                                 # items (HBM)
        ],
        out_specs=pl.BlockSpec((1, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m), jnp.int32),
        scratch_shapes=[
            pltpu.SMEM((1, m), jnp.int32),
            pltpu.VMEM((1, m), jnp.int32),
            pltpu.VMEM((1, dp), jnp.float32),
            pltpu.VMEM((m, dp), jnp.float32),
            pltpu.SemaphoreType.DMA((m + 3,)),
        ],
        interpret=interpret,
    )(utgt, bucket_ids, bucket_scores, adj, items)
