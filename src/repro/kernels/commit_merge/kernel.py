"""Fused commit-merge Pallas TPU kernel — the reverse-link top-M merge of the
batched Algorithm-2 commit, one tile of ``T`` distinct targets per grid step,
entirely in VMEM.

The reference path (``commit_merge_ref``) builds an ``E·(M+1)``-row edge
table (every proposal plus every existing edge of every touched target) and
pushes it through TWO device-wide ``lax.sort`` passes, materializing the
``[E, M, d]`` gathered neighbor vectors and the full table in HBM between
stages.  Here the wrapper (``ops.py``) buckets only the ``E`` proposals to
target rows with ONE E-row sort, packs ``T`` rows per grid step, and each
step finishes its tile of touched rows on-chip:

  1. DMA each live target's adjacency row HBM->SMEM (scalar ids for the
     gather loop) and HBM->VMEM (vector lanes), and each target's item
     vector HBM->VMEM — T targets' copies all started before any wait;
  2. DMA the tile's T·M existing-neighbor item rows HBM->VMEM (same
     explicit-DMA idiom as ``beam_step``: the ids are read from the rows
     *inside* the kernel, so a scalar-prefetch BlockSpec cannot express
     them);
  3. rescore the existing edges against their target vector (MXU, one
     [1, M]·[M, dp] dot per tile row), drop existing slots that duplicate a
     proposal (the proposal's score wins) or an earlier existing slot;
  4. rank proposals + surviving existing edges with the ``ranked_top_m``
     selection network — batched over the T tile rows — and write the
     tile's new top-M id rows.

Only the final ``[T, M]`` id rows return to HBM per step.  The wrapper
compacts live targets to a contiguous bucket-row prefix, so a fully-pad tile
(every ``target < 0``) skips every DMA and emits all ``-1`` rows that the
wrapper scatters into a dummy slot; at most one tile per call is partially
live, and its dead rows fetch (and then fully mask) row 0.

``T = 1`` degenerates to the original one-target-per-step layout, which is
how the pre-tiling grid remains expressible (and tested).

VMEM budget per step: T·(M+1)·dp·4 (target + neighbor rows) + T·(2K + 3M)
words — ~105 KB for T=8, M=16, dp=128, K=512 (~140 KB counting the tile's
bucket input blocks); far under the ~16 MB/core limit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def ranked_top_m(ids, scores, valid, m: int):
    """Top-``m`` of ``[B, C]`` candidates by (score desc, id asc), honoring an
    explicit ``valid`` mask.  Returns ``[B, m]`` int32 ids, ``-1`` padded.

    Differs from ``topk_merge.masked_top_l`` in two contract points that the
    commit merge needs: ties resolve by *smallest id* (the reference's stable
    rank over the (target, cand)-sorted table), not by slot position, and a
    valid slot may carry ``-inf`` and still outrank emptiness (the reference
    keeps valid ``-inf``-score edges when the row has spare capacity).
    Requires ids unique among valid slots — one hit per pass, like the
    reference's deduped table.  Statically unrolled compare/select trees.
    """
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    avail = valid
    out = []
    for _ in range(m):
        has = jnp.any(avail, axis=1)
        mx = jnp.max(jnp.where(avail, scores, NEG_INF), axis=1)
        tied = avail & (scores == mx[:, None])
        cmin = jnp.min(jnp.where(tied, ids, big), axis=1)
        hit = tied & (ids == cmin[:, None])
        out.append(jnp.where(has, cmin, -1))
        avail &= ~hit
    return jnp.stack(out, axis=1).astype(jnp.int32)


def _commit_merge_kernel(
    tgt_ref, bi_ref, bs_ref,          # VMEM-blocked inputs (one target tile)
    adj_hbm, items_hbm,               # whole arrays, ANY/HBM
    out_ref,                          # [T, M] new row ids
    adj_smem, adj_vmem, tvec_ref, rows_ref, sems,
    *,
    m: int,
    t: int,
):
    tgt = tgt_ref[...]                                # [T, 1]
    live = tgt >= 0                                   # [T, 1]
    # The wrapper compacts live targets to a bucket-row prefix, so a tile
    # with a dead first row is entirely pad and skips all DMA (its outputs
    # are fully masked by ``live`` below, so stale/uninitialized scratch
    # contents are never observable).  Dead rows inside the one partially
    # live tile fall through with clamped ids and fetch row 0 harmlessly.
    live_any = tgt_ref[0, 0] >= 0

    @pl.when(live_any)
    def _fetch():
        # --- 1. adjacency rows (SMEM scalars + VMEM lanes) + target vectors —
        # all T targets' copies started before any wait, so the fetches
        # overlap on TPU.  ``i`` is a static Python index (T is static).
        def _adj_s(i):
            ti = jnp.maximum(tgt_ref[i, 0], 0)
            return pltpu.make_async_copy(
                adj_hbm.at[pl.ds(ti, 1), :], adj_smem.at[pl.ds(i, 1), :],
                sems.at[t * m + i],
            )

        def _adj_v(i):
            ti = jnp.maximum(tgt_ref[i, 0], 0)
            return pltpu.make_async_copy(
                adj_hbm.at[pl.ds(ti, 1), :], adj_vmem.at[pl.ds(i, 1), :],
                sems.at[t * m + t + i],
            )

        def _tv(i):
            ti = jnp.maximum(tgt_ref[i, 0], 0)
            return pltpu.make_async_copy(
                items_hbm.at[pl.ds(ti, 1), :], tvec_ref.at[pl.ds(i, 1), :],
                sems.at[t * m + 2 * t + i],
            )

        for i in range(t):
            _adj_s(i).start()
            _adj_v(i).start()
            _tv(i).start()
        for i in range(t):
            _adj_s(i).wait()

        # --- 2. gather the T·M existing-neighbor rows (start all, wait all) —
        # neighbor ids come from the adjacency rows just landed in SMEM; the
        # flat row index p maps to (tile row p // M, slot p % M).
        def _row_copy(p):
            nid = jnp.maximum(adj_smem[p // m, p % m], 0)
            return pltpu.make_async_copy(
                items_hbm.at[pl.ds(nid, 1), :], rows_ref.at[pl.ds(p, 1), :],
                sems.at[p],
            )

        jax.lax.fori_loop(0, t * m, lambda p, c: (_row_copy(p).start(), c)[1], 0)
        jax.lax.fori_loop(0, t * m, lambda p, c: (_row_copy(p).wait(), c)[1], 0)
        for i in range(t):
            _adj_v(i).wait()
            _tv(i).wait()

    # --- 3. dedup + rescore — all in VMEM, batched over the T tile rows ----
    new_ids = bi_ref[...]                             # [T, K] (-1 padded)
    new_valid = (new_ids >= 0) & live
    new_scores = jnp.where(new_valid, bs_ref[...], NEG_INF)

    ex_ids = adj_vmem[...]                            # [T, M]
    # existing slot duplicated by a proposal -> dropped (proposal score wins)
    in_new = (
        (ex_ids[:, :, None] == new_ids[:, None, :]) & new_valid[:, None, :]
    ).any(axis=-1)
    # existing slot repeating an earlier existing slot -> dropped (keep first)
    eq = ex_ids[:, :, None] == ex_ids[:, None, :]
    jj = jax.lax.broadcasted_iota(jnp.int32, (t, m, m), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (t, m, m), 2)
    ex_dup = (eq & (kk < jj)).any(axis=-1)
    ex_valid = (ex_ids >= 0) & live & ~in_new & ~ex_dup

    tvec = tvec_ref[...]                              # [T, dp]
    rows = rows_ref[...]                              # [T*M, dp]
    ex_scores = jnp.concatenate(
        [
            jax.lax.dot_general(
                tvec[i : i + 1, :], rows[i * m : (i + 1) * m, :],
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            for i in range(t)
        ],
        axis=0,
    )                                                 # [T, M]
    ex_scores = jnp.where(ex_valid, ex_scores, NEG_INF)

    # --- 4. rank and rewrite the tile's rows --------------------------------
    cand_i = jnp.concatenate(
        [jnp.where(new_valid, new_ids, -1), jnp.where(ex_valid, ex_ids, -1)],
        axis=1,
    )
    cand_s = jnp.concatenate([new_scores, ex_scores], axis=1)
    cand_v = jnp.concatenate([new_valid, ex_valid], axis=1)
    out_ref[...] = ranked_top_m(cand_i, cand_s, cand_v, m)


def commit_merge_pallas(
    utgt: jax.Array,          # [G, 1] int32 unique targets (-1 pad rows,
    #                           live rows a contiguous prefix)
    bucket_ids: jax.Array,    # [G, K] int32 deduped proposal ids (-1 padded)
    bucket_scores: jax.Array, # [G, K] fp32 proposal scores
    adj: jax.Array,           # [N, M] int32 (-1 padded)
    items: jax.Array,         # [N, dp] fp32, dp a lane multiple
    *,
    tile: int = 1,
    interpret: bool = True,
):
    """One fused reverse-link merge step per tile of ``tile`` unique targets.
    ``G`` must be a multiple of ``tile`` (the wrapper pads the bucket table).
    Returns the ``[G, M]`` rewritten row ids (all ``-1`` for pad rows); the
    wrapper owns the bucketing pre-pass, the tile padding, and the row
    scatter."""
    g = utgt.shape[0]
    k = bucket_ids.shape[1]
    m = adj.shape[1]
    dp = items.shape[1]
    if g % tile:
        raise ValueError(f"bucket rows ({g}) must be a multiple of tile ({tile})")

    spec_any = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    return pl.pallas_call(
        functools.partial(_commit_merge_kernel, m=m, t=tile),
        grid=(g // tile,),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),   # target ids
            pl.BlockSpec((tile, k), lambda i: (i, 0)),   # proposal ids
            pl.BlockSpec((tile, k), lambda i: (i, 0)),   # proposal scores
            spec_any,                                    # adj (HBM)
            spec_any,                                    # items (HBM)
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m), jnp.int32),
        scratch_shapes=[
            pltpu.SMEM((tile, m), jnp.int32),
            pltpu.VMEM((tile, m), jnp.int32),
            pltpu.VMEM((tile, dp), jnp.float32),
            pltpu.VMEM((tile * m, dp), jnp.float32),
            pltpu.SemaphoreType.DMA((tile * (m + 3),)),
        ],
        interpret=interpret,
    )(utgt, bucket_ids, bucket_scores, adj, items)
