"""jit'd wrapper for commit_merge: buckets the [E] proposal table to target
rows, packs them into tiles of ``commit_tile`` distinct targets per grid
step, and exposes the commit_merge_ref signature so
``core.build.commit_batch`` can dispatch to it as a commit backend.

Bucketing pre-pass (the only global work left — ONE stable E-row lex-sort by
(target, cand), vs the reference's two (E·(M+1))-row device-wide sorts):

  1. sort the proposals by (target, cand); adjacent equal pairs are
     duplicates — all but the first (= first in input order, the sort is
     stable) are dropped, which is exactly the reference's pass-1 semantics;
  2. segment boundaries of the sorted target column enumerate the unique
     targets; each surviving proposal gets (segment id, position within
     segment) and is scattered into a fixed-width ``[E, K]`` bucket table —
     compacted (live targets occupy a contiguous row prefix), and in
     cand-ascending order within a row, which is the tie order the kernel's
     ranking must reproduce;
  3. the bucket table is padded to a multiple of ``commit_tile`` rows and
     the kernel rewrites one TILE of up to ``commit_tile`` target rows per
     grid step (fully-pad tiles skip all DMA and emit ``-1`` rows into a
     dummy scatter slot), and a single row-granular scatter puts the
     rewritten rows back.

The tiling reclaims the pad grid steps the one-target-per-step layout burned
on repeated-target batches: the grid shrinks from ``E`` steps to
``ceil(E / T)`` while staying statically sized for the all-unique worst
case, so a batch whose proposals collapse onto ``U << E`` distinct targets
(the paper's hub in-degree skew, PAPER.md §4) runs ``ceil(U/T)`` live steps
instead of ``U`` — and only ``ceil(E/T) - ceil(U/T)`` (cheap, DMA-free) pad
steps instead of ``E - U``.  ``benchmarks/build_bench.py`` measures the
reclaim as ``pad_step_frac`` (see docs/BENCHMARKS.md for the exact
definition).

``resolve_commit_tile`` is the tiling planner: ``commit_tile`` may be a
positive int or ``"auto"``, which picks the tile from the norm skew of the
items when concrete norms are available (heavier skew -> stronger hub
concentration -> more duplicate targets per batch -> larger tiles pay off;
the same skew motivates the norm-aware partitioning of Norm-Ranging LSH).
The tile must be static (it is the kernel's grid geometry), so build drivers
resolve ``"auto"`` on host BEFORE entering jit/scan; inside a trace the
planner falls back to ``DEFAULT_COMMIT_TILE``.

``max_cands`` bounds the bucket width K = the number of DISTINCT cand ids a
single target can receive.  ``commit_batch`` passes its insert-batch size B
(each batch row proposes itself at most once per target after dedup); the
default ``min(E, N)`` is always sufficient.  Overflow beyond a too-small
caller-supplied bound is dropped silently — sizing K is the caller contract.

Padding note: the feature axis is zero-padded to the 128 lane width, which
keeps fp32 inner products bit-identical (same rule as beam_step), so the
rescored existing edges rank exactly as the reference's unpadded einsum.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.commit_merge.kernel import commit_merge_pallas

# The planner's trace-time fallback and the skew ladder it climbs: duplicate
# targets come from hub in-degree, which every profile shows at paper scale
# (~0.8 of proposal slots collapse, ROADMAP PR-3 measurement), so even the
# flat-norm floor tiles 4 targets per step.
DEFAULT_COMMIT_TILE = 8
MAX_COMMIT_TILE = 32


def resolve_commit_tile(
    commit_tile: Union[int, str],
    *,
    e: Optional[int] = None,
    norms: Optional[jax.Array] = None,
) -> int:
    """The tiling planner: resolve the ``commit_tile`` knob to a static tile.

    ``commit_tile`` is a positive int (used as-is, clamped to the proposal
    count ``e``) or ``"auto"``: pick the tile from the norm skew of
    ``norms`` — the coefficient of variation of the item norms, a cheap
    host-side proxy for how hard the batch's reverse-link targets collapse
    onto large-norm hubs (PAPER.md §4 / Fig. 4).  Flat norms (e.g. the
    angular graph's unit norms) still duplicate via in-degree skew, so the
    ladder floors at 4; the heavy lognormal tail earns the 16-target tile.
    ``norms`` may be omitted or traced (inside jit/vmap/scan the skew is not
    concrete), in which case ``"auto"`` falls back to DEFAULT_COMMIT_TILE —
    build drivers therefore resolve ``"auto"`` on host before tracing.
    """
    if isinstance(commit_tile, (bool,)) or (
        not isinstance(commit_tile, (int, np.integer)) and commit_tile != "auto"
    ):
        raise ValueError(
            f"commit_tile must be a positive int or 'auto', got {commit_tile!r}"
        )
    if commit_tile == "auto":
        t = DEFAULT_COMMIT_TILE
        if norms is not None and not isinstance(norms, jax.core.Tracer):
            n = np.asarray(norms, np.float64).ravel()
            if n.size and np.all(np.isfinite(n)) and n.mean() > 0:
                cv = float(n.std() / n.mean())
                t = 4 if cv < 0.15 else (8 if cv < 0.6 else 16)
    else:
        t = int(commit_tile)
        if t < 1:
            raise ValueError(
                f"commit_tile must be a positive int or 'auto', got {commit_tile!r}"
            )
    if e is not None:
        t = max(1, min(t, int(e)))
    return min(t, MAX_COMMIT_TILE)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit, static_argnames=("max_cands", "commit_tile", "interpret")
)
def commit_merge(
    adj: jax.Array,
    items: jax.Array,
    targets: jax.Array,   # [E] int32 reverse-edge targets (-1 invalid)
    cands: jax.Array,     # [E] int32 candidate neighbors (-1 invalid)
    scores: jax.Array,    # [E] fp32 s(target, cand)
    *,
    max_cands: Optional[int] = None,
    commit_tile: Union[int, str] = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Drop-in for commit_merge_ref backed by the fused Pallas kernel.
    ``commit_tile`` targets are merged per grid step (``"auto"`` resolves via
    the planner — pass a pre-resolved int to honor the norm-skew heuristic,
    see resolve_commit_tile).  ``interpret=None`` auto-falls back to
    interpret mode off-TPU."""
    n, m = adj.shape
    e = targets.shape[0]
    if e == 0:
        return adj
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = max_cands if max_cands is not None else min(e, n)
    k = max(min(k, e), 1)
    tile = resolve_commit_tile(commit_tile, e=e)

    d = items.shape[-1]
    dp = _round_up(d, 128)
    items_pad = jnp.pad(items.astype(jnp.float32), ((0, 0), (0, dp - d)))

    # --- bucket the proposals: one stable E-row lex-sort by (target, cand) --
    big = jnp.int32(n + 1)
    targets = targets.astype(jnp.int32)
    k1 = jnp.where(targets >= 0, targets, big)
    k2 = jnp.where((targets >= 0) & (cands >= 0), cands.astype(jnp.int32), big)
    k1s, k2s, c_s, s_s = jax.lax.sort(
        (k1, k2, cands.astype(jnp.int32), scores.astype(jnp.float32)),
        num_keys=2, is_stable=True,
    )
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (k1s[1:] == k1s[:-1]) & (k2s[1:] == k2s[:-1])]
    )
    v_b = (k1s < big) & (k2s < big) & ~dup          # survives into a bucket
    new_t = jnp.concatenate(
        [k1s[:1] < big, (k1s[1:] != k1s[:-1]) & (k1s[1:] < big)]
    )                                               # first entry of a target
    seg = jnp.cumsum(new_t.astype(jnp.int32)) - 1   # unique-target index
    cv = jnp.cumsum(v_b.astype(jnp.int32))
    base = jax.lax.cummax(jnp.where(new_t, cv - v_b.astype(jnp.int32), 0))
    pos = cv - 1 - base                             # slot within the bucket

    # g bucket rows, padded to whole tiles; live targets occupy rows 0..U-1
    # (the sort puts valid keys first), which is the prefix invariant the
    # kernel's per-tile DMA skip relies on.
    g = _round_up(e, tile)
    row = jnp.where(v_b, seg, g)
    col = jnp.where(v_b, pos, 0)
    bucket_ids = (
        jnp.full((g, k), -1, jnp.int32).at[row, col].set(c_s, mode="drop")
    )
    bucket_scores = (
        jnp.zeros((g, k), jnp.float32).at[row, col].set(s_s, mode="drop")
    )
    urow = jnp.where(new_t, seg, g)
    utgt = (
        jnp.full((g, 1), -1, jnp.int32)
        .at[urow, 0].set(jnp.where(new_t, k1s, 0), mode="drop")
    )

    # --- per-tile VMEM merge + one row-granular scatter back ----------------
    out_rows = commit_merge_pallas(
        utgt, bucket_ids, bucket_scores, adj.astype(jnp.int32), items_pad,
        tile=tile, interpret=interpret,
    )
    adj_pad = jnp.concatenate([adj, jnp.full((1, m), -1, adj.dtype)], axis=0)
    wrow = jnp.where(utgt[:, 0] >= 0, utgt[:, 0], n)  # pad rows -> dummy row
    return adj_pad.at[wrow].set(out_rows.astype(adj.dtype))[:n]
