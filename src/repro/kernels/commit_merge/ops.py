"""jit'd wrapper for commit_merge: buckets the [E] proposal table to target
tiles and exposes the commit_merge_ref signature so
``core.build.commit_batch`` can dispatch to it as a commit backend.

Bucketing pre-pass (the only global work left — ONE stable E-row lex-sort by
(target, cand), vs the reference's two (E·(M+1))-row device-wide sorts):

  1. sort the proposals by (target, cand); adjacent equal pairs are
     duplicates — all but the first (= first in input order, the sort is
     stable) are dropped, which is exactly the reference's pass-1 semantics;
  2. segment boundaries of the sorted target column enumerate the unique
     targets; each surviving proposal gets (segment id, position within
     segment) and is scattered into a fixed-width ``[E, K]`` bucket table —
     compacted, and in cand-ascending order within a row, which is the tie
     order the kernel's ranking must reproduce;
  3. the kernel rewrites one row per unique target (pad steps for the
     all-unique worst case emit ``-1`` rows into a dummy slot), and a single
     row-granular scatter puts the rewritten rows back.

``max_cands`` bounds the bucket width K = the number of DISTINCT cand ids a
single target can receive.  ``commit_batch`` passes its insert-batch size B
(each batch row proposes itself at most once per target after dedup); the
default ``min(E, N)`` is always sufficient.  Overflow beyond a too-small
caller-supplied bound is dropped silently — sizing K is the caller contract.

Padding note: the feature axis is zero-padded to the 128 lane width, which
keeps fp32 inner products bit-identical (same rule as beam_step), so the
rescored existing edges rank exactly as the reference's unpadded einsum.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.commit_merge.kernel import commit_merge_pallas


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("max_cands", "interpret"))
def commit_merge(
    adj: jax.Array,
    items: jax.Array,
    targets: jax.Array,   # [E] int32 reverse-edge targets (-1 invalid)
    cands: jax.Array,     # [E] int32 candidate neighbors (-1 invalid)
    scores: jax.Array,    # [E] fp32 s(target, cand)
    *,
    max_cands: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Drop-in for commit_merge_ref backed by the fused Pallas kernel.
    ``interpret=None`` auto-falls back to interpret mode off-TPU."""
    n, m = adj.shape
    e = targets.shape[0]
    if e == 0:
        return adj
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = max_cands if max_cands is not None else min(e, n)
    k = max(min(k, e), 1)

    d = items.shape[-1]
    dp = _round_up(d, 128)
    items_pad = jnp.pad(items.astype(jnp.float32), ((0, 0), (0, dp - d)))

    # --- bucket the proposals: one stable E-row lex-sort by (target, cand) --
    big = jnp.int32(n + 1)
    targets = targets.astype(jnp.int32)
    k1 = jnp.where(targets >= 0, targets, big)
    k2 = jnp.where((targets >= 0) & (cands >= 0), cands.astype(jnp.int32), big)
    k1s, k2s, c_s, s_s = jax.lax.sort(
        (k1, k2, cands.astype(jnp.int32), scores.astype(jnp.float32)),
        num_keys=2, is_stable=True,
    )
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (k1s[1:] == k1s[:-1]) & (k2s[1:] == k2s[:-1])]
    )
    v_b = (k1s < big) & (k2s < big) & ~dup          # survives into a bucket
    new_t = jnp.concatenate(
        [k1s[:1] < big, (k1s[1:] != k1s[:-1]) & (k1s[1:] < big)]
    )                                               # first entry of a target
    seg = jnp.cumsum(new_t.astype(jnp.int32)) - 1   # unique-target index
    cv = jnp.cumsum(v_b.astype(jnp.int32))
    base = jax.lax.cummax(jnp.where(new_t, cv - v_b.astype(jnp.int32), 0))
    pos = cv - 1 - base                             # slot within the bucket

    row = jnp.where(v_b, seg, e)
    col = jnp.where(v_b, pos, 0)
    bucket_ids = (
        jnp.full((e, k), -1, jnp.int32).at[row, col].set(c_s, mode="drop")
    )
    bucket_scores = (
        jnp.zeros((e, k), jnp.float32).at[row, col].set(s_s, mode="drop")
    )
    urow = jnp.where(new_t, seg, e)
    utgt = (
        jnp.full((e, 1), -1, jnp.int32)
        .at[urow, 0].set(jnp.where(new_t, k1s, 0), mode="drop")
    )

    # --- per-tile VMEM merge + one row-granular scatter back ----------------
    out_rows = commit_merge_pallas(
        utgt, bucket_ids, bucket_scores, adj.astype(jnp.int32), items_pad,
        interpret=interpret,
    )
    adj_pad = jnp.concatenate([adj, jnp.full((1, m), -1, adj.dtype)], axis=0)
    wrow = jnp.where(utgt[:, 0] >= 0, utgt[:, 0], n)  # pad rows -> dummy row
    return adj_pad.at[wrow].set(out_rows.astype(adj.dtype))[:n]
