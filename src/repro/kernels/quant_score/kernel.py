"""Fused gather + dequant + dot Pallas TPU kernel — the quantized
beam-expansion hot loop (DESIGN.md §8).

Same scalar-prefetch shape as gather_score: neighbor ids are prefetched into
SMEM and the code-row BlockSpec's index_map uses them to DMA exactly the
needed int8 rows HBM->VMEM — 1 byte per element instead of gather_score's 4,
which is the whole point of the int8 store.  The row is cast to fp32 in
VMEM ("rescale in VMEM, accumulate fp32"), dotted with the query, and scaled
by the row's dequant factor fetched through the same index_map from the
``[N, 1]`` scales column.

Ids must be pre-clamped to [0, N); -1 masking is the ops.py wrapper's job
(the quant_score contract masks -1 to -inf, see ref.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_score_kernel(ids_ref, q_ref, c_ref, s_ref, o_ref):
    # q [1, d] fp32; c [1, d] int8 (one gathered code row); s [1, 1] fp32.
    row = c_ref[0, :].astype(jnp.float32)
    o_ref[0, 0] = (
        jnp.sum(q_ref[0, :] * row, dtype=jnp.float32) * s_ref[0, 0]
    )


def quant_score_pallas(
    queries: jax.Array,   # [B, d] fp32
    codes: jax.Array,     # [N, d] int8
    scales: jax.Array,    # [N, 1] fp32 (column layout — scalar blocks)
    ids: jax.Array,       # [B, W] int32 in [0, N)
    *,
    interpret: bool = True,
):
    """scores [B, W] fp32 with scores[b, w] =
    (queries[b] . codes[ids[b, w]]) * scales[ids[b, w]]."""
    b, d = queries.shape
    w = ids.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, w),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i, j], 0)),
            pl.BlockSpec((1, 1), lambda i, j, ids_ref: (ids_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
    )
    return pl.pallas_call(
        _quant_score_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.float32),
        interpret=interpret,
    )(ids, queries, codes, scales)
