"""jit'd public wrapper for quant_score: clamps -1 ids for the gather,
reshapes the scales to the kernel's column layout, and applies the contract
mask (-1 ids -> -inf) so the output matches the ref.py oracle exactly.

``interpret=None`` auto-falls back to interpret mode off-TPU, like the other
fused kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.quant_score.kernel import quant_score_pallas
from repro.kernels.quant_score.ref import NEG_INF


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_score(
    queries: jax.Array,   # [B, d]
    codes: jax.Array,     # [N, d] int8
    scales: jax.Array,    # [N] fp32
    ids: jax.Array,       # [B, W] int32, -1 padded
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Drop-in for quant_score_ref backed by the fused Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    safe = jnp.maximum(ids.astype(jnp.int32), 0)
    out = quant_score_pallas(
        queries.astype(jnp.float32),
        codes,
        scales.reshape(-1, 1).astype(jnp.float32),
        safe,
        interpret=interpret,
    )
    return jnp.where(ids >= 0, out, NEG_INF)
