"""Pure-jnp oracle for the quant_score kernel — the quantized-score
convention's single source of truth (DESIGN.md §8):

    s~(q, i) = (q . codes_i) * scales_i        (fp32 dot over cast codes,
                                                then ONE multiply per score)

-1 ids are masked to -inf *inside* the oracle (unlike gather_score, whose
caller owns masking): the quantized walk and the exact-rerank pool both
carry -1 padding, so the mask is part of the scoring contract here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def quant_score_ref(
    queries: jax.Array,   # [B, d] fp32
    codes: jax.Array,     # [N, d] int8
    scales: jax.Array,    # [N] fp32
    ids: jax.Array,       # [B, W] int32, -1 padded
) -> jax.Array:
    """Per-query gathered dequant-scores, [B, W] fp32; -1 ids -> -inf."""
    safe = jnp.maximum(ids, 0)
    rows = codes[safe].astype(jnp.float32)  # [B, W, d]
    s = jnp.einsum(
        "bd,bwd->bw",
        queries.astype(jnp.float32),
        rows,
        preferred_element_type=jnp.float32,
    )
    s = s * scales[safe]
    return jnp.where(ids >= 0, s, NEG_INF)
