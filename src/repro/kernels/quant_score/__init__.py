from repro.kernels.quant_score.ops import quant_score
from repro.kernels.quant_score.ref import quant_score_ref

__all__ = ["quant_score", "quant_score_ref"]
