"""Pure-jnp oracle for topk_merge (matches the jnp pool update used in
search.beam_search: concat + top_k + take_along_axis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_merge_ref(pool_s, pool_i, pool_c, new_s, new_i, new_c):
    cand_s = jnp.concatenate([pool_s, new_s], axis=1)
    cand_i = jnp.concatenate([pool_i, new_i], axis=1)
    cand_c = jnp.concatenate([pool_c, new_c], axis=1)
    l = pool_s.shape[1]
    vals, sel = jax.lax.top_k(cand_s, l)
    return (
        vals,
        jnp.take_along_axis(cand_i, sel, axis=1),
        jnp.take_along_axis(cand_c, sel, axis=1),
    )
