"""Two-pool top-k merge Pallas TPU kernel — the candidate-pool update of
Algorithm 1 (line 7-8: sort C, resize to l) without an HBM round-trip.

Merges the current pool (L sorted slots) with the M freshly-scored neighbors
per query, carrying two payloads (id, checked-flag), entirely in VMEM.
Selection is the same L-pass masked-max network as mips_topk (static unroll,
no sort/gather primitives — lowers to VPU compare/select trees on TPU).

grid = (B/bb,): one query tile per step; everything fits VMEM
  (bb * (2L + 2(L+M)) * 4 bytes ≈ 100 KB for bb=128, L=64, M=16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def masked_top_l(cand_s, cand_i, cand_c, l: int):
    """Select the top-``l`` of ``[B, C]`` score rows with two int payloads.

    The L-pass masked-max network matches ``lax.top_k`` tie-breaking exactly
    (first occurrence wins), so callers get bit-identical ids to the jnp
    oracle.  Picked slots are excluded by an availability mask rather than by
    overwriting their score with -inf: real candidate pools legitimately hold
    -inf scores (empty/-1 slots), and overwriting would tie them with the
    already-picked slots and re-emit a picked payload instead of advancing to
    the first unpicked slot.  Statically unrolled — lowers to VPU
    compare/select trees; also the merge stage of the fused beam_step kernel.
    """
    c = cand_s.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, cand_s.shape, 1)
    avail = jnp.ones(cand_s.shape, dtype=bool)
    out_s, out_i, out_c = [], [], []
    for _ in range(l):
        m = jnp.max(jnp.where(avail, cand_s, NEG_INF), axis=1)
        tied = avail & (cand_s == m[:, None])
        amax = jnp.min(jnp.where(tied, col, c), axis=1)
        hit = col == amax[:, None]
        out_s.append(m)
        out_i.append(jnp.max(jnp.where(hit, cand_i, -1), axis=1))
        out_c.append(jnp.max(jnp.where(hit, cand_c, 0), axis=1))
        avail &= ~hit
    return (
        jnp.stack(out_s, axis=1),
        jnp.stack(out_i, axis=1),
        jnp.stack(out_c, axis=1),
    )


def _merge_kernel(
    ps_ref, pi_ref, pc_ref, ns_ref, ni_ref, nc_ref, os_ref, oi_ref, oc_ref, *, l: int
):
    cand_s = jnp.concatenate([ps_ref[...], ns_ref[...]], axis=1)
    cand_i = jnp.concatenate([pi_ref[...], ni_ref[...]], axis=1)
    cand_c = jnp.concatenate([pc_ref[...], nc_ref[...]], axis=1)
    os_ref[...], oi_ref[...], oc_ref[...] = masked_top_l(cand_s, cand_i, cand_c, l)


def topk_merge_pallas(
    pool_s, pool_i, pool_c, new_s, new_i, new_c, *, bb: int = 128, interpret: bool = True
):
    """pool_*: [B, L] (fp32 / int32 / int32 0-1 flag); new_*: [B, M].
    Returns merged top-L (scores, ids, checked) by descending score."""
    b, l = pool_s.shape
    m = new_s.shape[1]
    assert b % bb == 0 or b < bb, (b, bb)
    bb = min(bb, b)
    grid = (b // bb,)
    kernel = functools.partial(_merge_kernel, l=l)
    specs_pool = pl.BlockSpec((bb, l), lambda i: (i, 0))
    specs_new = pl.BlockSpec((bb, m), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[specs_pool, specs_pool, specs_pool, specs_new, specs_new, specs_new],
        out_specs=(specs_pool, specs_pool, specs_pool),
        out_shape=(
            jax.ShapeDtypeStruct((b, l), jnp.float32),
            jax.ShapeDtypeStruct((b, l), jnp.int32),
            jax.ShapeDtypeStruct((b, l), jnp.int32),
        ),
        interpret=interpret,
    )(pool_s, pool_i, pool_c, new_s, new_i, new_c)
