"""jit'd wrapper for topk_merge: pads B to the tile multiple."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_merge.kernel import topk_merge_pallas, NEG_INF


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_merge(pool_s, pool_i, pool_c, new_s, new_i, new_c, *, interpret: bool = True):
    b = pool_s.shape[0]
    bb = min(128, b)
    bp = -(-b // bb) * bb
    pad = lambda a, fill: jnp.pad(a, ((0, bp - b), (0, 0)), constant_values=fill)
    s, i, c = topk_merge_pallas(
        pad(pool_s.astype(jnp.float32), NEG_INF),
        pad(pool_i.astype(jnp.int32), -1),
        pad(pool_c.astype(jnp.int32), 0),
        pad(new_s.astype(jnp.float32), NEG_INF),
        pad(new_i.astype(jnp.int32), -1),
        pad(new_c.astype(jnp.int32), 0),
        bb=bb,
        interpret=interpret,
    )
    return s[:b], i[:b], c[:b]
