"""MIPS serving launcher — the paper's technique as the candidate-generation
stage (--index ipnsw_plus), the ip-NSW baseline, or the exact scan.

  PYTHONPATH=src python -m repro.launch.serve --index ipnsw_plus \
      --n-items 20000 --batch 256 --ef 40 [--shards 4] \
      [--backend pallas] [--build-backend scan] [--commit-backend pallas] \
      [--commit-tile auto|N] [--storage int8|tiered] \
      [--partition norm_bands] [--route upper_bound]

With --shards > 1, items are row-sharded into shard-local sub-indexes and
queries fan out via shard_map (requires that many local devices; use
XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU).
``--partition norm_bands`` cuts the catalog into descending-norm bands and
``--route upper_bound`` lets each query skip shards whose Cauchy-Schwarz
bound cannot reach its running k-th score (core/distributed.py); the report
then carries shards_visited_mean / skipped_mean.  ``--storage tiered``
serves the hot top band f32 and the cold bands int8.

``--loop`` switches from the one-shot timed batch to the continuous-batching
serving loop (launch/serve_loop.py): a Poisson request trace is scheduled
through the deadline-aware bucket ladder and the report gains p50/p99
latency, QPS, occupancy and the recompile split (warmup vs steady state —
steady-state recompiles mean the bucket ladder regressed and must be zero).
``--clock virtual`` (default) runs deterministic simulated time;
``--clock wall`` serves in real time.  Not combinable with --shards > 1.

Every mode reports the process-wide XLA compile-event count
(serve_loop.xla_compile_events, a jax.monitoring hook) so compile creep is
visible even outside loop mode.
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import IpNSW, IpNSWPlus, exact_topk, recall_at_k
from repro.data import mips_dataset, mips_queries
from repro.launch import serve_loop as sl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default="ipnsw_plus",
                    choices=["bruteforce", "ipnsw", "ipnsw_plus"])
    ap.add_argument("--n-items", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=40)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--profile", default="lognormal")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="walk step backend (search.STEP_BACKENDS)")
    ap.add_argument("--build-backend", default="host",
                    choices=["host", "scan"],
                    help="insertion driver (build.BUILD_BACKENDS)")
    ap.add_argument("--commit-backend", default="reference",
                    choices=["reference", "pallas"],
                    help="reverse-link merge kernel (build.COMMIT_BACKENDS)")
    ap.add_argument("--commit-tile", default="auto",
                    type=lambda s: s if s == "auto" else int(s),
                    help="targets merged per fused-commit grid step: a "
                         "positive int, or 'auto' to let the planner pick "
                         "from the norm skew (DESIGN.md §7)")
    ap.add_argument("--storage", default="f32",
                    choices=["f32", "int8", "tiered"],
                    help="item store the walks stream "
                         "(storage.STORAGE_BACKENDS; int8 = quantized walk "
                         "+ exact fp32 rerank, DESIGN.md §8; tiered = hot "
                         "top band f32, cold bands int8 — sharded only, "
                         "needs --route upper_bound)")
    ap.add_argument("--partition", default="roundrobin",
                    choices=["roundrobin", "norm_bands"],
                    help="sharded catalog split "
                         "(distributed.PARTITION_BACKENDS; norm_bands = "
                         "count-balanced bands of descending ||x|| with "
                         "per-shard max_norm routing bounds)")
    ap.add_argument("--route", default="none",
                    choices=["none", "upper_bound"],
                    help="sharded query routing (distributed.ROUTE_MODES; "
                         "upper_bound skips shards whose max_norm*||q|| "
                         "cannot beat the running k-th score)")
    ap.add_argument("--loop", action="store_true",
                    help="continuous-batching serving loop instead of the "
                         "one-shot timed batch (launch/serve_loop.py)")
    ap.add_argument("--clock", default="virtual",
                    choices=["virtual", "wall"],
                    help="loop mode time source: deterministic simulated "
                         "time, or real time")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="loop mode Poisson arrival rate (QPS)")
    ap.add_argument("--requests", type=int, default=256,
                    help="loop mode trace length")
    ap.add_argument("--churn-trace", type=float, default=0.0, metavar="FRAC",
                    help="loop mode: wrap the index in a MutableIndex and "
                         "replay a seeded churn trace turning over FRAC of "
                         "the catalog (upserts + tombstone deletes + one "
                         "hub-kill) interleaved with the query traffic "
                         "(core/mutation.py)")
    ap.add_argument("--relink-budget", type=int, default=64,
                    help="nodes repaired per scheduled relink pass of the "
                         "churn trace (0 disables periodic repair)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot on exit: *.prom = "
                         "Prometheus text, anything else = JSONL with the "
                         "event timeline (render with scripts/obs_report.py)")
    ap.add_argument("--trace", action="store_true",
                    help="thread an obs.TraceContext through every walk: "
                         "per-norm-band eval histograms + hub hits ride "
                         "along at unchanged walk outputs (repro.obs)")
    args = ap.parse_args()

    if args.shards <= 1 and (args.route != "none"
                             or args.partition != "roundrobin"
                             or args.storage == "tiered"):
        raise SystemExit("--partition/--route/--storage tiered shape the "
                         "sharded fan-out; add --shards N")
    if args.storage == "tiered" and args.route != "upper_bound":
        raise SystemExit("--storage tiered rides the routed two-phase walk; "
                         "add --route upper_bound")

    compile_events0 = sl.xla_compile_events()

    items = jnp.asarray(mips_dataset(args.n_items, args.dim, args.profile, seed=0))
    queries = jnp.asarray(mips_queries(args.batch, args.dim, seed=1))
    _, gt = exact_topk(queries, items, k=args.k)
    gt = np.asarray(gt)

    if args.trace and (args.shards > 1 or args.index == "bruteforce"):
        raise SystemExit("--trace instruments graph walks on one device; "
                         "drop --shards / pick a graph index")

    if args.loop:
        if args.shards > 1 or args.index == "bruteforce":
            raise SystemExit("--loop serves ipnsw/ipnsw_plus on one device; "
                             "drop --shards / pick a graph index")
        _run_loop(args, items, compile_events0)
        return

    trace_ctx = None
    route_note = ""
    if args.shards > 1:
        from repro.core.distributed import build_sharded, sharded_search

        assert len(jax.devices()) >= args.shards, (
            f"need {args.shards} devices; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={args.shards}"
        )
        index = build_sharded(items, args.shards,
                              plus=args.index == "ipnsw_plus",
                              build_backend=args.build_backend,
                              backend=args.backend,
                              commit_backend=args.commit_backend,
                              commit_tile=args.commit_tile,
                              storage=args.storage,
                              partition=args.partition,
                              max_degree=16, ef_construction=32,
                              insert_batch=512)
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((args.shards,), ("model",))
        # jit the whole fan-out: sharded_search alone rebuilds its shard_map
        # closure per call, so without this the "warmup" would not cache
        # anything and the timed call would still pay trace+compile.
        # Routing happens INSIDE the program (two-phase masked walk) so the
        # jit stays compile-once; return_stats threads the visit counts out.
        search = jax.jit(functools.partial(
            sharded_search, mesh=mesh, k=args.k, ef=args.ef,
            backend=args.backend, storage=args.storage,
            route=args.route, return_stats=True,
            plus=args.index == "ipnsw_plus"))
        jax.block_until_ready(search(index, queries)[0])  # compile warmup
        t0 = time.perf_counter()
        ids, _, evals, rstats = search(index, queries)
        jax.block_until_ready(ids)
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(ids), gt)
        ev = float(np.mean(np.asarray(evals)))
        visited = float(np.mean(np.asarray(rstats.shards_visited)))
        skipped = float(np.mean(np.asarray(rstats.bound_skips)))
        route_note = (f"partition={args.partition} route={args.route} "
                      f"shards_visited_mean={visited:.2f} "
                      f"skipped_mean={skipped:.2f} ")
    elif args.index == "bruteforce":
        t0 = time.perf_counter()
        _, ids = exact_topk(queries, items, k=args.k)
        jax.block_until_ready(ids)
        dt = time.perf_counter() - t0
        rec, ev = recall_at_k(np.asarray(ids), gt), float(args.n_items)
    else:
        cls = IpNSWPlus if args.index == "ipnsw_plus" else IpNSW
        index = cls(max_degree=16, ef_construction=32, insert_batch=512,
                    backend=args.backend,
                    build_backend=args.build_backend,
                    commit_backend=args.commit_backend,
                    commit_tile=args.commit_tile,
                    storage=args.storage).build(items)
        if args.trace:
            trace_ctx = _trace_context(index)
        r = index.search(queries, k=args.k, ef=args.ef,
                         trace=trace_ctx)  # compile warmup
        jax.block_until_ready(r.ids)
        t0 = time.perf_counter()
        r = index.search(queries, k=args.k, ef=args.ef, trace=trace_ctx)
        jax.block_until_ready(r.ids)
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(r.ids), gt)
        ev = float(np.mean(np.asarray(r.evals)))
        if trace_ctx is not None:
            from repro.obs import get_registry

            band = np.asarray(r.trace.band_hist).sum(axis=0)
            get_registry().vector(
                "walk_evals_by_band", band.shape[0],
                "similarity evaluations per catalog norm band (Fig-5)",
                label="band",
            ).add(band)
            get_registry().counter(
                "walk_hub_evals_total",
                "evaluations landing on the top-in-degree hub set (Fig-4)",
            ).inc(int(np.asarray(r.trace.hub_evals).sum()))

    print(f"[serve] index={args.index} shards={args.shards} "
          f"storage={args.storage} {route_note}"
          f"N={args.n_items} B={args.batch} ef={args.ef}: "
          f"recall@{args.k}={rec:.3f} evals/q={ev:.0f} "
          f"({dt/args.batch*1e3:.2f} ms/query batch-amortized) "
          f"xla_compiles={sl.xla_compile_events() - compile_events0}")
    if trace_ctx is not None:
        from repro.obs import get_registry

        _print_band_table(get_registry(), trace_ctx)
    if args.metrics_out:
        from repro.obs import get_registry

        _write_metrics(get_registry(), args.metrics_out)


def _trace_context(index, size=None):
    """An obs.TraceContext over the index the walks will actually run on:
    raw-item norms (the ip graph for ip-NSW+ — the walk the paper's norm
    bias lives in) and its adjacency for the hub set.  MutableIndex passes
    its padded capacity arrays with ``size=`` the real catalog so band
    edges fit the true norm distribution."""
    from repro.core.mutation import MutableIndex
    from repro.obs import make_trace_context

    if isinstance(index, MutableIndex):
        g = index.graph
        norms = np.asarray(index.norms)
    else:
        g = index.ip_graph if isinstance(index, IpNSWPlus) else index.graph
        norms = np.linalg.norm(np.asarray(g.items), axis=1)
    return make_trace_context(norms, np.asarray(g.adj), size=size)


def _write_metrics(registry, path: str, meta=None) -> None:
    from repro.obs import write_metrics

    full = {"tool": "repro.launch.serve"}
    full.update(meta or {})
    fmt = write_metrics(registry, path, meta=full)
    print(f"[serve] metrics snapshot ({fmt}) -> {path}")


def _print_band_table(registry, trace_ctx) -> None:
    from repro.obs import render_band_table

    vec = registry.get("walk_evals_by_band")
    if vec is None:
        print("[serve] no traced walks recorded")
        return
    print("[serve] evals by catalog norm band (band 0 = smallest norms):")
    print(render_band_table(vec.values, np.asarray(trace_ctx.band_edges)))


def _build_ladder(batch: int, ef: int) -> "sl.BucketLadder":
    """A small ladder bracketing the CLI's (batch, ef): quarter/full batch
    rungs and quarter/half/full ef rungs (deduped, floored at 8)."""
    batches = tuple(sorted({max(1, batch // 4), batch}))
    efs = tuple(sorted({max(8, ef // 4), max(8, ef // 2), ef}))
    return sl.BucketLadder(batches=batches, efs=efs)


def _run_loop(args, items, compile_events0: int) -> None:
    cls = IpNSWPlus if args.index == "ipnsw_plus" else IpNSW
    index = cls(max_degree=16, ef_construction=32, insert_batch=512,
                backend=args.backend,
                build_backend=args.build_backend,
                commit_backend=args.commit_backend,
                commit_tile=args.commit_tile,
                storage=args.storage).build(items)

    queries = mips_queries(args.requests, args.dim, seed=1)
    _, gt = exact_topk(jnp.asarray(queries), items, k=args.k)
    gt = np.asarray(gt)

    ladder = _build_ladder(args.batch, args.ef)
    trace = sl.poisson_trace(
        queries, rate_qps=args.rate, seed=2, ef=args.ef,
        classes=("interactive", "standard", "relaxed"),
    )
    churn = None
    if args.churn_trace > 0:
        from repro.core import ChurnTrace, MutableIndex

        index = MutableIndex(index, capacity=int(args.n_items * 1.25))
        dur = max(r.arrival_t for r in trace) + 1e-3
        churn = ChurnTrace.generate(
            n_items=args.n_items, dim=args.dim, duration_s=dur,
            turnover=args.churn_trace, batch=32, seed=3,
            profile=args.profile, hub_kill_at=dur / 2, hub_kill_k=8,
            relink_every=dur / 4 if args.relink_budget else None,
            relink_budget=args.relink_budget,
        )
    registry = trace_ctx = None
    if args.metrics_out or args.trace:
        from repro.obs import MetricsRegistry

        registry = MetricsRegistry()
    if args.trace:
        trace_ctx = _trace_context(index, size=args.n_items)

    clock = sl.VirtualClock() if args.clock == "virtual" else sl.WallClock()
    loop = sl.ServeLoop(index, ladder=ladder, clock=clock, k=args.k,
                        service_model=sl.LinearServiceModel(),
                        registry=registry, trace_ctx=trace_ctx)
    stats = loop.run(trace, churn=churn)

    by_rid = sorted(stats.responses, key=lambda r: r.rid)
    rec = recall_at_k(np.stack([r.ids for r in by_rid]), gt)
    s = stats.summary()
    print(f"[serve --loop] index={args.index} storage={args.storage} "
          f"clock={args.clock} N={args.n_items} rate={args.rate:.0f}qps "
          f"requests={args.requests} "
          f"ladder={'/'.join(f'{b.batch}x{b.ef}' for b in ladder.buckets())}: "
          f"recall@{args.k}={rec:.3f} p50={s['p50_ms']:.2f}ms "
          f"p99={s['p99_ms']:.2f}ms qps={s['qps']:.0f} "
          f"occupancy={s['occupancy']:.2f} "
          f"miss_frac={s['deadline_miss_frac']:.3f} "
          f"recompiles(warmup/steady)={s['recompiles_warmup']}"
          f"/{s['recompiles_steady']} "
          f"xla_compiles={sl.xla_compile_events() - compile_events0}")
    if churn is not None:
        print(f"[serve --loop] churn: events={s['mutation_events']} "
              f"rejected={s['rejected']} "
              f"live_frac={s['health_live_fraction']:.3f} "
              f"dead_edge_frac={s['health_dead_edge_frac']:.3f} "
              f"relink_debt={s['health_relink_debt']:.0f}")
    if trace_ctx is not None:
        _print_band_table(registry, trace_ctx)
    if args.metrics_out:
        meta = {"mode": "loop", "index": args.index, "clock": args.clock,
                "profile": args.profile, "n_items": args.n_items,
                "rate_qps": args.rate, "requests": args.requests,
                "traced": bool(args.trace)}
        if trace_ctx is not None:
            meta["band_edges"] = [
                float(e) for e in np.asarray(trace_ctx.band_edges)
            ]
        _write_metrics(registry, args.metrics_out, meta=meta)
    if s["recompiles_steady"]:
        raise SystemExit(
            f"bucket-ladder regression: {s['recompiles_steady']} "
            "steady-state recompiles (expected 0)"
        )


if __name__ == "__main__":
    main()
