"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --scale tiny --steps 50 --ckpt-dir /tmp/ckpt

On this CPU container, --scale tiny trains a reduced config of the arch's
family (the full configs are exercised via dryrun.py).  On a real pod the
same entry point runs the full config: the step functions, shardings and
checkpoint protocol are identical — only the mesh and config scale change.
Preemption-safe: re-running the same command resumes from the last committed
checkpoint; stragglers are logged by the loop's EWMA watchdog.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import SyntheticClickStream, SyntheticLMStream
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.train import adamw_init, adamw_update, cosine_schedule, loop


def _tiny_lm(cfg):
    pat = tuple((64 if w is not None else None) for w in cfg.window_pattern)
    return dataclasses.replace(
        cfg, n_layers=2 * len(pat), d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256 if not cfg.is_moe else 128, vocab=1024,
        moe_experts=4 if cfg.is_moe else 0, moe_top_k=2 if cfg.is_moe else 0,
        window_pattern=pat, dtype=jnp.float32, attn_chunk=64, remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", default="tiny", choices=["tiny"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    key = jax.random.PRNGKey(0)

    if arch.family == "lm":
        cfg = _tiny_lm(arch.cfg)
        params, _ = tf.init(key, cfg)
        stream = SyntheticLMStream(cfg.vocab, args.batch, args.seq)
        loss_fn = lambda p, b: tf.lm_loss(p, b, cfg)
    elif arch.family == "gnn":
        cfg = dataclasses.replace(arch.base, n_layers=4, d_hidden=64,
                                  d_feat=32, d_edge=4)
        params, _ = gnn_mod.init(key, cfg)
        rng = np.random.default_rng(0)
        n, e = 512, 2048

        class GraphStream:
            def batch_at(self, step):
                r = np.random.default_rng(step)
                return dict(
                    node_feat=r.normal(size=(n, 32)).astype(np.float32),
                    edge_feat=r.normal(size=(e, 4)).astype(np.float32),
                    src=rng.integers(0, n, e).astype(np.int32),
                    dst=rng.integers(0, n, e).astype(np.int32),
                    targets=r.normal(size=(n, cfg.out_dim)).astype(np.float32),
                )

        stream = GraphStream()
        loss_fn = lambda p, b: gnn_mod.mse_loss(p, b, cfg)
    else:  # recsys
        from repro.configs.common import _RECSYS_MODS

        mod = _RECSYS_MODS[args.arch]
        cfg = dataclasses.replace(arch.cfg, n_items=10_000) \
            if hasattr(arch.cfg, "n_items") else dataclasses.replace(arch.cfg, n_rows=10_000)
        params = mod._init_params(key, cfg)
        stream = SyntheticClickStream(10_000, args.batch, getattr(cfg, "seq_len", 50))
        loss_map = {
            "dlrm-rm2": lambda p, b: mod.bce_loss(p, b, cfg),
            "sasrec": lambda p, b: mod.sampled_softmax_loss(p, b, cfg),
            "mind": lambda p, b: mod.sampled_softmax_loss(p, b, cfg),
            "dien": lambda p, b: mod.bce_loss(p, b, cfg),
        }
        loss_fn = loss_map[args.arch]

    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def step_fn(state, batch):
        batch = jax.tree.map(jnp.asarray, batch)
        l, g = jax.value_and_grad(loss_fn)(state["params"], batch)
        lr = cosine_schedule(state["opt"].step, base_lr=args.lr,
                             warmup=max(args.steps // 10, 1), total=args.steps)
        p, o = adamw_update(g, state["opt"], state["params"], lr=lr)
        return {"params": p, "opt": o}, {"loss": l}

    res = loop.run(step_fn, state, stream, n_steps=args.steps,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[train] {args.arch}: loss {res.history[0]['loss']:.4f} -> "
          f"{res.history[-1]['loss']:.4f} over {len(res.history)} steps; "
          f"{len(res.straggler_steps)} straggler steps")


if __name__ == "__main__":
    main()
