import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
      --shape train_4k --mesh both --out experiments/dryrun

The XLA_FLAGS line above MUST run before any jax import (device count locks
at first init); nothing else in the repo sets it globally.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.configs.common import shardings
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# effective wire-byte multiplier per collective kind (ring algorithms)
_WIRE_FACTOR = {
    "all-reduce": 2.0,       # reduce-scatter + all-gather phases
    "all-gather": 1.0,       # result bytes received
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind, parsed from optimized HLO.
    Shapes in the post-SPMD module are per-device (local) shapes; '-done' ops
    are skipped so async pairs count once."""
    by_kind: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_type)
        wire = b * _WIRE_FACTOR[kind]
        by_kind.setdefault(kind, dict(ops=0, result_bytes=0, wire_bytes=0.0))
        by_kind[kind]["ops"] += 1
        by_kind[kind]["result_bytes"] += b
        by_kind[kind]["wire_bytes"] += wire
        count += 1
    total_wire = sum(k["wire_bytes"] for k in by_kind.values())
    return {"ops": count, "by_kind": by_kind, "wire_bytes": total_wire}


def run_cell(
    arch_id: str,
    shape: str,
    multi_pod: bool,
    verbose: bool = True,
    hlo_path: str | None = None,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = get_arch(arch_id)
    t0 = time.time()
    cell = arch.build_cell(shape, mesh)
    in_sh = shardings(mesh, cell.in_specs)
    out_sh = (
        shardings(mesh, cell.out_specs) if cell.out_specs is not None else None
    )
    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
        if mem:
            mem["peak_bytes_per_device"] = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0)
            )
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = repr(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in ca:
                cost[k] = float(ca[k])
    except Exception as e:
        cost["error"] = repr(e)

    hlo_text = compiled.as_text()
    coll = collective_stats(hlo_text)
    if hlo_path is not None:
        import gzip

        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo_text)

    record = {
        "cell": cell.name,
        "arch": arch_id,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": coll,
        "meta": cell.meta,
    }
    if verbose:
        print(
            f"[dryrun] {cell.name} mesh={record['mesh']}: "
            f"compile {t_compile:.1f}s, "
            f"flops/dev {cost.get('flops', float('nan')):.3e}, "
            f"bytes/dev {cost.get('bytes accessed', float('nan')):.3e}, "
            f"wire/dev {coll['wire_bytes']:.3e} ({coll['ops']} collectives)"
        )
        if "peak_bytes_per_device" in mem:
            print(
                f"         args {mem['argument_size_in_bytes']/2**30:.2f} GiB"
                f" + temp {mem['temp_size_in_bytes']/2**30:.2f} GiB"
                f" + out {mem['output_size_in_bytes']/2**30:.2f} GiB per device"
            )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch_id, shape in cells:
        for multi in meshes:
            tag = f"{arch_id}__{shape}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] skip (exists): {tag}")
                continue
            try:
                rec = run_cell(
                    arch_id, shape, multi,
                    hlo_path=os.path.join(args.out, tag + ".hlo.txt.gz"),
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception:
                failures.append(tag)
                traceback.print_exc()
                with open(path + ".failed", "w") as f:
                    f.write(traceback.format_exc())
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
