"""Roofline analysis from the compiled dry-run artifacts.

Why not just ``compiled.cost_analysis()``: XLA's flat cost analysis counts a
while-loop body ONCE, so scan-over-layers models under-report by ~n_layers.
This module re-derives loop-aware totals from the optimized HLO text:

  1. parse every computation + its top-level ops;
  2. recover while trip counts from the loop-condition's compare constant;
  3. propagate multipliers through the call graph (while bodies x trip count,
     fusions/calls x caller);
  4. FLOPs   — from dot ops' shapes x contracting dims (matmuls dominate all
     ten architectures; elementwise flops are ignored, consistent with the
     6*N*D convention);
  5. bytes   — sum of (result + operand) sizes of top-level ops (post-fusion
     HLO materializes exactly these buffers to HBM; fusion internals are
     fused away);
  6. wire    — collective result bytes x ring factors (2x for all-reduce).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI/link.
All quantities are PER DEVICE (the compiled module is the per-device SPMD
program), so terms are seconds-per-step on the production mesh.
"""
from __future__ import annotations

import dataclasses
import glob
import gzip
import json
import os
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation headers may have tuple-typed params (nested parens) — match
# only the leading name.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+([\w\-]+)\("
)
_CALL_ATTR = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")

WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call", "copy-start", "copy-done",
}


def _shape_dims(type_str: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        out.append((dtype, d))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    kind: str
    line: str

    def operand_names(self) -> List[str]:
        """Names referenced inside the op's argument parens (optimized HLO
        prints operands without types)."""
        i = self.line.index("(")
        depth = 0
        j = i
        for j in range(i, len(self.line)):
            if self.line[j] == "(":
                depth += 1
            elif self.line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        inside = self.line[i + 1 : j]
        return re.findall(r"%([\w\.\-]+)", inside)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    calls: List[str]
    while_pairs: List[tuple]  # (cond_name, body_name)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if (
            line
            and not line[0].isspace()
            and line.rstrip().endswith("{")
            and "(" in line
        ):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], [], [])
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind = m.groups()
        op = Op(name, rtype, kind, line)
        cur.ops.append(op)
        if kind == "while":
            w = _WHILE_RE.search(line)
            if w:
                cur.while_pairs.append((w.group(1), w.group(2)))
        for callee in _CALL_ATTR.findall(line):
            cur.calls.append(callee)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — the standard XLA
    counted-loop pattern compares the induction variable against it."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution count per computation, ENTRY = 1; while bodies multiply by
    trip count; everything else inherits the caller's count."""
    entry = None
    for name in comps:
        # ENTRY computation is the one nobody calls
        entry = name
    called = set()
    for c in comps.values():
        called.update(c.calls)
    roots = [n for n in comps if n not in called]
    mult: Dict[str, float] = {n: 0.0 for n in comps}

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 50:
            return
        mult[name] += m
        c = comps[name]
        wb = {b: cn for cn, b in c.while_pairs}
        wc = {cn for cn, _ in c.while_pairs}
        seen = set()
        for callee in c.calls:
            if callee in seen:
                continue
            seen.add(callee)
            if callee in wb:  # while body: multiply by trip count
                tc = _trip_count(comps[wb[callee]]) if wb[callee] in comps else 1
                visit(callee, m * max(tc, 1), depth + 1)
            elif callee in wc:  # condition: runs tc+1 times; negligible
                visit(callee, m, depth + 1)
            else:
                visit(callee, m, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult


_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(op: Op, table: Dict[str, str]) -> float:
    """2 x prod(result dims) x prod(contracted dims of lhs)."""
    res = _shape_dims(op.result_type)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    m = _DOT_DIMS.search(op.line)
    names = op.operand_names()
    if not m or not names:
        return 0.0
    lhs_type = table.get(names[0], "")
    lhs = _shape_dims(lhs_type)
    if not lhs:
        return 0.0
    lhs_dims = lhs[0][1]
    k = 1
    for idx in (int(x) for x in m.group(1).split(",") if x):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> dict:
    comps = parse_module(text)
    mult = _multipliers(comps)

    flops = 0.0
    bytes_hbm = 0.0
    wire = 0.0
    coll_by_kind: Dict[str, float] = {}
    coll_f32_promoted_total = [0.0]
    fusion_names = {
        c for c in comps if c.startswith("fused") or "fused_computation" in c
        or c.startswith("wrapped_")
    }

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        table = {op.name: op.result_type for op in comp.ops}
        in_fusion = cname in fusion_names
        for op in comp.ops:
            base_kind = op.kind.replace("-start", "").replace("-done", "")
            if op.kind == "dot":
                flops += m * _dot_flops(op, table)
            if in_fusion:
                continue  # fusion internals don't touch HBM
            if base_kind in WIRE_FACTOR:
                if op.kind.endswith("-done"):
                    continue
                b = _shape_bytes(op.result_type)
                if base_kind == "reduce-scatter":
                    # wire ~= reduced operand, not the scattered result
                    names = op.operand_names()
                    b = sum(
                        _shape_bytes(table[nm]) for nm in names if nm in table
                    ) or b
                # TPU model correction: XLA:CPU's float-normalization pass
                # promotes bf16 collectives to f32 (CPU has no native bf16
                # reductions) — visible as convert fusions feeding every
                # large AR.  The TPU backend executes them in bf16.  All
                # large activation/gradient collectives in this codebase
                # are bf16 by construction (params/activations bf16; the
                # only true-f32 reductions are scalar losses/stats), so
                # large f32 payloads are halved.  Raw bytes are kept in
                # f32_promoted_bytes for transparency.
                raw = b
                if "f32[" in op.result_type and b > (1 << 22):
                    b = b // 2
                    wire_f32_promoted = raw - b
                else:
                    wire_f32_promoted = 0
                coll_f32_promoted = coll_f32_promoted_total[0] = (
                    coll_f32_promoted_total[0] + m * wire_f32_promoted
                )
                wire += m * b * WIRE_FACTOR[base_kind]
                coll_by_kind[base_kind] = coll_by_kind.get(base_kind, 0.0) + m * b
                bytes_hbm += m * b
                continue
            if op.kind in _CONTROL_OPS:
                if op.kind == "custom-call":
                    bytes_hbm += m * _shape_bytes(op.result_type)
                continue
            # HBM traffic estimate per op kind.  Index-driven ops touch only
            # the selected region, NOT their full operand (a dynamic-slice of
            # the stacked layer weights inside a scan must not count the
            # whole stack every iteration).
            res_b = _shape_bytes(op.result_type)
            if op.kind in ("dynamic-slice", "slice", "gather", "broadcast",
                           "reshape", "transpose", "copy", "convert",
                           "concatenate", "reverse", "pad"):
                bytes_hbm += m * 2 * res_b
            elif op.kind in ("dynamic-update-slice", "scatter"):
                names = op.operand_names()
                upd_idx = 1 if op.kind == "dynamic-update-slice" else 2
                upd = (
                    _shape_bytes(table[names[upd_idx]])
                    if len(names) > upd_idx and names[upd_idx] in table
                    else res_b
                )
                bytes_hbm += m * 2 * upd
            else:
                opnd = sum(
                    _shape_bytes(table[nm])
                    for nm in op.operand_names()
                    if nm in table
                )
                bytes_hbm += m * (res_b + opnd)

    return dict(
        flops=flops,
        bytes=bytes_hbm,
        wire_bytes=wire,
        f32_promoted_bytes=coll_f32_promoted_total[0],
        coll_by_kind=coll_by_kind,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_hbm / HBM_BW,
        collective_s=wire / LINK_BW,
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS per cell (the "useful work" yardstick)
# ---------------------------------------------------------------------------


def model_flops(meta: dict, n_devices: int) -> float:
    fam = meta["family"]
    if fam == "lm":
        n = meta["active_params"]
        d = meta["tokens"]
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[meta["kind"]]
        return mult * n * d / n_devices
    if fam == "gnn":
        h = meta["d_hidden"]
        e, nn, L = meta["n_edges"], meta["n_nodes"], meta["n_layers"]
        per_edge = 2 * (3 * h * h + h * h + h * h)   # edge MLP [3h->h->h->h]
        per_node = 2 * (2 * h * h + h * h + h * h)   # node MLP [2h->h->h->h]
        fwd = L * (e * per_edge + nn * per_node)
        return 3.0 * fwd / n_devices                  # train: fwd + 2x bwd
    # recsys — MLP/attention flops per sample (embedding gathers are bytes,
    # not flops)
    b = meta["batch"]
    per_sample = {
        "dlrm-rm2": 2 * (13 * 512 + 512 * 256 + 256 * 64 + 415 * 512 + 512 * 512 + 512 * 256 + 256),
        "sasrec": 2 * (2 * (3 * 50 * 50 + 2 * 50 * 50) * 50 + 2 * 50 * 50 * 50),
        "mind": 2 * (50 * 64 * 64 * 3),
        "dien": 2 * (100 * (3 * (18 * 108 + 108 * 108) + 3 * (108 * 108 * 2)) + 126 * 200 + 200 * 80),
    }[meta["arch"]]
    mult = 3.0 if meta["kind"] == "train" else 1.0
    if meta["kind"] == "retrieval":
        per_sample += 2 * meta["n_cand"] * 64
    return mult * b * per_sample / n_devices


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def analyze_record(json_path: str) -> dict:
    with open(json_path) as f:
        rec = json.load(f)
    hlo_path = json_path.replace(".json", ".hlo.txt.gz")
    terms = {}
    if os.path.exists(hlo_path):
        with gzip.open(hlo_path, "rt") as f:
            terms = analyze_hlo(f.read())
    mf = model_flops(rec["meta"], rec["n_devices"])
    out = dict(
        cell=rec["cell"],
        mesh=rec["mesh"],
        n_devices=rec["n_devices"],
        model_flops_per_dev=mf,
        hlo_flops_flat=rec["cost"].get("flops", 0.0),
        **terms,
    )
    if terms:
        out["useful_ratio"] = mf / max(terms["flops"], 1.0)
        dom = max(
            ("compute", terms["compute_s"]),
            ("memory", terms["memory_s"]),
            ("collective", terms["collective_s"]),
            key=lambda kv: kv[1],
        )
        out["dominant"] = dom[0]
        out["step_s_bound"] = dom[1]
        denom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"], 1e-30)
        out["roofline_fraction"] = (mf / PEAK_FLOPS) / denom
    mem = rec.get("memory", {})
    if "peak_bytes_per_device" in mem:
        out["mem_gib_per_dev"] = round(mem["peak_bytes_per_device"] / 2**30, 2)
    return out


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    args = ap.parse_args()

    rows = []
    for p in sorted(glob.glob(os.path.join(args.dryrun_dir, "*.json"))):
        if args.mesh == "single" and "__multi" in p:
            continue
        if args.mesh == "multi" and "__single" in p:
            continue
        try:
            rows.append(analyze_record(p))
        except Exception as e:  # noqa
            rows.append(dict(cell=os.path.basename(p), error=repr(e)))

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    hdr = f"{'cell':42s} {'mesh':8s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'dom':>10s} {'roofline%':>9s} {'GiB/dev':>8s}"
    print(hdr)
    for r in rows:
        if "error" in r:
            print(f"{r['cell']:42s} ERROR {r['error']}")
            continue
        print(
            f"{r['cell']:42s} {r['mesh']:8s} "
            f"{r.get('compute_s', float('nan')):10.3e} "
            f"{r.get('memory_s', float('nan')):10.3e} "
            f"{r.get('collective_s', float('nan')):10.3e} "
            f"{r.get('dominant', '?'):>10s} "
            f"{100*r.get('roofline_fraction', 0):8.1f}% "
            f"{r.get('mem_gib_per_dev', float('nan')):8.2f}"
        )


if __name__ == "__main__":
    main()
