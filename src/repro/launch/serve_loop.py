"""Continuous-batching serving loop — the multi-user layer over the index.

Everything below ``serve.py``'s one-shot CLI so far optimizes a single
pre-formed batch; this module turns the repo into a server.  The moving
parts, in dataflow order:

  request queue  — ``Request``s carry a query, an arrival time, a deadline
                   and an ``ef`` preference (the paper's per-request
                   recall/latency dial, fig 8c).  Arrivals come from any
                   iterable; ``poisson_trace`` builds the open-loop Poisson
                   load the benchmarks use.
  scheduler      — coalesces queued requests into dynamic batches.
                   Admission is deadline-ordered (earliest deadline first,
                   which is FIFO within a deadline class since every member
                   of a class shares one budget); the batch is padded up to
                   a ``BucketLadder`` shape and served at the LARGEST ladder
                   ``ef`` that (a) no batched request asked to exceed and
                   (b) the ``ServiceModel`` predicts still meets the
                   tightest deadline — degrading to a smaller ``ef`` rather
                   than rejecting, and at the ladder floor (late) when
                   nothing fits.  Requests are never rejected.
  bucket ladder  — the small fixed set of (batch, ef) shapes.  Each bucket
                   is ONE persistent jitted ``beam_search`` program
                   (``BucketExecutor``): fixed shapes + static knobs mean
                   compile-once, zero steady-state recompiles; the padded
                   query buffer is donated to XLA where the backend supports
                   donation.  Pad rows ride the ``valid=`` mask of
                   ``core.search.beam_search`` (born done, ids=-1, zero
                   evals) so a live row's result is bit-identical to a solo
                   search — the padding-equivalence pin.
  clock          — every time read goes through an injectable clock.
                   ``VirtualClock`` + a deterministic ``ServiceModel`` make
                   the whole loop a pure function of the arrival trace
                   (bit-identical replay, no wall-clock flakiness);
                   ``WallClock`` serves real traffic.  ServeLoop itself
                   never imports wall time — tests pin that.
  response demux — each request gets back exactly its row of the bucket
                   result, stamped with dispatch/finish times and the ef it
                   was actually served at.

Churn: ``run(churn=)`` replays a seeded ``core.mutation.ChurnTrace``
(upserts, tombstone deletes, adversarial hub kills, relink repair passes)
against a ``MutableIndex``-backed executor, interleaved with query traffic —
events apply between dispatches when the loop clock passes their timestamps,
and ``ServeStats`` carries the post-run churn health counters.

Observability: ``BucketExecutor`` counts compile-cache misses on the
bucketed entry point (bucket shapes are fixed, so a program-build per bucket
is exactly one XLA compile), split into warmup vs steady-state — a bucket
ladder regression shows up as ``recompiles_steady > 0``.  A module-level
``jax.monitoring`` listener additionally counts raw XLA compile events as a
cross-check (``xla_compile_events()``), which ``serve.py`` reports.
Beyond the built-in counters, ``ServeLoop(registry=...)`` streams queue
wait, coalesce size, occupancy, degrades, deadline misses, churn health and
a dispatch/response event timeline into a ``repro.obs`` MetricsRegistry,
and ``trace_ctx=`` threads an ``obs.TraceContext`` through every dispatch
so per-norm-band walk histograms ride along (docs/ARCHITECTURE.md,
"The observability layer").  Every registry record carries LOOP-clock
values — the no-wall-time property above is preserved, and a VirtualClock
run exports a deterministic registry.

See docs/ARCHITECTURE.md ("The serving layer") and benchmarks/serve_bench.py
for the p50/p99/QPS/occupancy rows built on top of this loop.
"""
from __future__ import annotations

import functools
import time  # WallClock only — the loop itself never reads wall time
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ipnsw import IpNSW
from repro.core.ipnsw_plus import IpNSWPlus
from repro.core.search import beam_search

# --------------------------------------------------------------------------
# XLA compile-event cross-check (jax.monitoring hook)
# --------------------------------------------------------------------------

_COMPILE_EVENTS = {"n": 0}


def _count_compile_event(event: str, *args, **kwargs) -> None:
    if "compile" in event:
        _COMPILE_EVENTS["n"] += 1


try:  # pragma: no cover - listener registration is environment-dependent
    from jax import monitoring as _jax_monitoring

    _jax_monitoring.register_event_listener(_count_compile_event)
    _jax_monitoring.register_event_duration_secs_listener(_count_compile_event)
except Exception:  # monitoring API absent/changed: executor counts remain
    pass


def xla_compile_events() -> int:
    """Raw XLA compile events observed process-wide since import (a
    cross-check for the executor's per-bucket cache-miss count)."""
    return _COMPILE_EVENTS["n"]


# --------------------------------------------------------------------------
# Clocks
# --------------------------------------------------------------------------


class VirtualClock:
    """Simulated time: advances only when the loop sleeps.  With a
    deterministic ServiceModel this makes a serve run a pure function of the
    arrival trace — the fake-clock test harness."""

    virtual = True

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def sleep_until(self, t: float) -> None:
        self._t = max(self._t, float(t))


class WallClock:
    """Real time, zeroed at construction so traces can start at t=0."""

    virtual = False

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


# --------------------------------------------------------------------------
# Requests / responses / deadline classes
# --------------------------------------------------------------------------

# Default per-class latency budgets (seconds past arrival).  Classes are
# names over budgets, nothing more: admission works on the absolute
# ``deadline_t`` each request carries.
DEADLINE_CLASSES: Dict[str, float] = {
    "interactive": 0.020,
    "standard": 0.100,
    "relaxed": 1.000,
}


@dataclass(frozen=True)
class Request:
    rid: int
    query: np.ndarray       # [d] fp32
    arrival_t: float
    deadline_t: float       # absolute time the response should exist by
    ef: int                 # requested recall dial (served ef never exceeds)
    klass: str = "standard"


@dataclass(frozen=True)
class Response:
    rid: int
    ids: np.ndarray         # [k] int32, -1 padded
    scores: np.ndarray      # [k] fp32
    ef_request: int
    ef_served: int
    bucket: "Bucket"
    arrival_t: float
    dispatch_t: float
    finish_t: float
    deadline_t: float
    deadline_met: bool
    degraded: bool          # served below the preferred ladder ef

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.arrival_t


@dataclass(frozen=True)
class BatchRecord:
    seq: int
    dispatch_t: float
    finish_t: float
    bucket: "Bucket"
    rids: Tuple[int, ...]
    ef_served: int

    @property
    def occupancy(self) -> float:
        return len(self.rids) / self.bucket.batch


# --------------------------------------------------------------------------
# Bucket ladder
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Bucket:
    batch: int
    ef: int


@dataclass(frozen=True)
class BucketLadder:
    """The fixed (batch, ef) shapes the loop is allowed to run — one
    compiled program each.  Both axes must be strictly ascending."""

    batches: Tuple[int, ...] = (4, 16)
    efs: Tuple[int, ...] = (16, 32, 64)

    def __post_init__(self):
        for name, axis in (("batches", self.batches), ("efs", self.efs)):
            if not axis or any(v <= 0 for v in axis):
                raise ValueError(f"ladder {name} must be positive: {axis}")
            if any(b >= a for a, b in zip(axis[1:], axis)):
                raise ValueError(f"ladder {name} must be strictly "
                                 f"ascending: {axis}")

    @property
    def max_batch(self) -> int:
        return self.batches[-1]

    def buckets(self) -> List[Bucket]:
        return [Bucket(b, e) for b in self.batches for e in self.efs]

    def batch_for(self, n: int) -> int:
        """Smallest ladder batch that holds n requests (n <= max_batch)."""
        for b in self.batches:
            if b >= n:
                return b
        raise ValueError(f"batch of {n} exceeds ladder max {self.max_batch}")

    def ef_pref(self, requested_ef: int) -> int:
        """Largest ladder ef not exceeding the request's dial (ladder floor
        when the request asks below every rung)."""
        fitting = [e for e in self.efs if e <= requested_ef]
        return fitting[-1] if fitting else self.efs[0]


# --------------------------------------------------------------------------
# Service model
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LinearServiceModel:
    """Deterministic bucket-cost prediction the scheduler plans with (and
    the amount a VirtualClock advances per dispatch).  Pure function of the
    bucket, so virtual-time runs replay bit-identically.  Constants are a
    knob, not a measurement — calibrate per deployment, or regress from
    serve_bench wall rows."""

    base_s: float = 1e-3          # per-dispatch overhead
    per_row_s: float = 1e-5       # per padded batch row
    per_ef_s: float = 0.0         # per ef unit, batch-independent
    per_ef_row_s: float = 1e-6    # per (row x ef) unit — the walk itself

    def service_s(self, bucket: Bucket) -> float:
        return (self.base_s
                + self.per_row_s * bucket.batch
                + self.per_ef_s * bucket.ef
                + self.per_ef_row_s * bucket.batch * bucket.ef)


# --------------------------------------------------------------------------
# Bucket executor — persistent jitted programs, recompile accounting
# --------------------------------------------------------------------------


def _ipnsw_bucket(graph, store, live, trace, queries, valid, *, k, ef,
                  backend, storage):
    b = queries.shape[0]
    init = jnp.broadcast_to(graph.entry[None, None], (b, 1)).astype(jnp.int32)
    r = beam_search(
        graph, queries, init, pool_size=max(ef, k), max_steps=2 * ef, k=k,
        backend=backend, storage=storage, store=store, valid=valid, live=live,
        trace=trace,
    )
    return r.ids, r.scores, r.evals, r.trace


def _plus_bucket(ang_graph, ip_graph, ang_store, ip_store, live, trace,
                 queries, valid, *, k, ef, ang_ef, k_angular, backend,
                 storage):
    from repro.core.ipnsw_plus import _search_plus

    r = _search_plus(
        ang_graph, ip_graph, queries, ang_store, ip_store, valid, live, trace,
        k=k, ef=ef, ang_ef=ang_ef, k_angular=k_angular,
        max_steps=2 * ef, ang_max_steps=2 * max(ang_ef, k_angular),
        backend=backend, storage=storage,
    )
    return r.ids, r.scores, r.evals, r.trace


class BucketExecutor:
    """One persistent jitted walk program per ladder bucket.

    A bucket fixes every shape (padded batch, pool size, step bound) and
    every static knob, so the program compiles exactly once; the executor's
    program-cache miss count IS the recompile count of the bucketed entry
    point, split into warmup (before ``warmup()`` returns) and steady-state
    (anything after — a ladder regression).  The padded query buffer is
    donated to XLA on backends that support input donation (TPU/GPU), which
    lets the runtime reuse it as scratch across dispatches.

    Accepts a ``core.mutation.MutableIndex`` too: graph/store/live then
    become per-dispatch ARGUMENTS of the jitted program rather than captured
    constants, so churn between dispatches is picked up immediately — and
    because mutations are in-place row updates (fixed capacity), the array
    shapes never change and the program cache still hits (zero steady-state
    recompiles under churn; pinned in tests/test_mutation.py).
    """

    def __init__(self, index, ladder: BucketLadder, *, k: int = 10,
                 donate: Optional[bool] = None, trace_ctx=None,
                 registry=None):
        from repro.core.mutation import MutableIndex

        self.mutable = index if isinstance(index, MutableIndex) else None
        if self.mutable is not None:
            index = index.index
        if not isinstance(index, (IpNSW, IpNSWPlus)):
            raise TypeError(
                f"BucketExecutor serves IpNSW, IpNSWPlus or MutableIndex, "
                f"got {type(index)}"
            )
        self.index = index
        self.ladder = ladder
        self.k = k
        if donate is None:  # CPU jax logs 'donation not implemented' warnings
            donate = jax.default_backend() in ("tpu", "gpu")
        self.donate = donate
        # Observability (repro.obs): trace_ctx threads walk telemetry through
        # every dispatch — it is an executor-lifetime constant, so the traced
        # program still compiles once per bucket (warmup already compiles the
        # traced shape; zero steady-state recompiles, pinned in
        # tests/test_obs.py).  registry receives the shape-free walk
        # aggregates per dispatch (the LOOP owns every time-stamped record —
        # the executor never reads any clock).  Both default off = the exact
        # pre-observability path.
        self.trace_ctx = trace_ctx
        self.registry = registry
        self.last_walk: Optional[Dict[str, np.ndarray]] = None
        self._programs: Dict[Bucket, object] = {}
        self.compile_log: List[Tuple[Bucket, str]] = []
        self._steady = False

    # -- accounting --------------------------------------------------------

    @property
    def recompiles_warmup(self) -> int:
        return sum(1 for _, phase in self.compile_log if phase == "warmup")

    @property
    def recompiles_steady(self) -> int:
        return sum(1 for _, phase in self.compile_log if phase == "steady")

    @property
    def warmed(self) -> bool:
        return self._steady

    # -- programs ----------------------------------------------------------

    def dim(self) -> int:
        g = self.index.ip_graph if isinstance(self.index, IpNSWPlus) \
            else self.index.graph
        assert g is not None, "index must be built before serving"
        return g.items.shape[1]

    def _consts(self):
        """The graph/store/live operands of the next dispatch.  For a plain
        index these are the same arrays every call; for a MutableIndex they
        are re-read so churn applied between dispatches is served
        immediately (same shapes either way — the jit cache keys hold)."""
        idx = self.index
        live = None if self.mutable is None else self.mutable.live
        if isinstance(idx, IpNSWPlus):
            if idx.storage == "int8" and idx.ip_store is None:
                idx._make_stores(idx.storage)
            return (
                idx.ang_graph, idx.ip_graph,
                idx.ang_store if idx.storage == "int8" else None,
                idx.ip_store if idx.storage == "int8" else None,
                live, self.trace_ctx,
            )
        return (idx.graph, idx._resolve_store(idx.storage), live,
                self.trace_ctx)

    def _build_program(self, bucket: Bucket):
        idx = self.index
        if isinstance(idx, IpNSWPlus):
            fn = functools.partial(
                _plus_bucket, k=self.k, ef=bucket.ef, ang_ef=idx.ang_ef,
                k_angular=idx.k_angular, backend=idx.backend,
                storage=idx.storage,
            )
            query_argnum = 6
        else:
            fn = functools.partial(
                _ipnsw_bucket, k=self.k, ef=bucket.ef, backend=idx.backend,
                storage=idx.storage,
            )
            query_argnum = 4
        jit_kwargs = {"donate_argnums": (query_argnum,)} if self.donate else {}
        return jax.jit(fn, **jit_kwargs)

    def warmup(self) -> None:
        """Compile every ladder bucket on an all-pad batch (the while_loop
        body never runs, so warmup is one trace+compile per bucket and zero
        walk work); everything after counts as steady state."""
        d = self.dim()
        for bucket in self.ladder.buckets():
            self.run(bucket,
                     np.zeros((bucket.batch, d), np.float32),
                     np.zeros((bucket.batch,), bool))
        self._steady = True

    def run(self, bucket: Bucket, queries: np.ndarray, valid: np.ndarray):
        """Dispatch one padded bucket; returns (ids, scores, evals) as
        host arrays.  ``queries`` [bucket.batch, d] fp32 is consumed (it may
        be donated) — callers build a fresh buffer per dispatch."""
        fn = self._programs.get(bucket)
        if fn is None:
            fn = self._build_program(bucket)
            self._programs[bucket] = fn
            self.compile_log.append(
                (bucket, "steady" if self._steady else "warmup")
            )
        ids, scores, evals, walk = fn(*self._consts(), jnp.asarray(queries),
                                      jnp.asarray(valid))
        self._record_walk(walk, np.asarray(valid))
        return np.asarray(ids), np.asarray(scores), np.asarray(evals)

    def _record_walk(self, walk, valid: np.ndarray) -> None:
        """Stash this dispatch's walk telemetry (``last_walk``: batch-summed
        band histogram, hub evals, steps) and fold it into the registry's
        always-on vectors/counters.  Pad rows contribute zero (born done —
        no evals, no visited entries), so no masking is needed beyond the
        row count.  Time-stamped events are the LOOP's job; nothing here
        reads a clock."""
        if walk is None:
            self.last_walk = None
            return
        band = np.asarray(walk.band_hist).sum(axis=0)
        hub = int(np.asarray(walk.hub_evals).sum())
        steps = np.asarray(walk.steps_to_converge)
        self.last_walk = {
            "band_hist": band,
            "hub_evals": hub,
            "steps_mean": float(steps[valid].mean()) if valid.any() else 0.0,
            "n": int(valid.sum()),
        }
        reg = self.registry
        if reg is not None:
            reg.vector(
                "walk_evals_by_band", band.shape[0],
                "similarity evaluations per catalog norm band (Fig-5)",
                label="band",
            ).add(band)
            reg.counter(
                "walk_hub_evals_total",
                "evaluations landing on the top-in-degree hub set (Fig-4)",
            ).inc(hub)
            reg.counter(
                "walk_evals_total", "total similarity evaluations",
            ).inc(float(band.sum()))


# --------------------------------------------------------------------------
# The serving loop
# --------------------------------------------------------------------------


@dataclass
class ServeStats:
    responses: List[Response]
    batches: List[BatchRecord]
    recompiles_warmup: int
    recompiles_steady: int
    # Churn observability (core/mutation.py; zeros/None without a churn
    # trace).  ``rejected`` pins the never-reject contract — the loop has no
    # rejection path, so anything nonzero is a logic regression.
    mutation_events: int = 0
    rejected: int = 0
    health: Optional[Dict[str, float]] = None

    def latencies_ms(self) -> np.ndarray:
        return np.asarray([r.latency_s * 1e3 for r in self.responses])

    def percentile_ms(self, q: float) -> float:
        lat = self.latencies_ms()
        return float(np.percentile(lat, q)) if lat.size else 0.0

    def qps(self) -> float:
        if not self.responses:
            return 0.0
        t0 = min(r.arrival_t for r in self.responses)
        t1 = max(r.finish_t for r in self.responses)
        return len(self.responses) / max(t1 - t0, 1e-12)

    def occupancy(self) -> float:
        if not self.batches:
            return 0.0
        return float(np.mean([b.occupancy for b in self.batches]))

    def deadline_miss_frac(self) -> float:
        if not self.responses:
            return 0.0
        return float(np.mean([not r.deadline_met for r in self.responses]))

    def summary(self) -> Dict[str, float]:
        out = {
            "served": len(self.responses),
            "batches": len(self.batches),
            "p50_ms": self.percentile_ms(50),
            "p99_ms": self.percentile_ms(99),
            "qps": self.qps(),
            "occupancy": self.occupancy(),
            "deadline_miss_frac": self.deadline_miss_frac(),
            "recompiles_warmup": self.recompiles_warmup,
            "recompiles_steady": self.recompiles_steady,
            "mutation_events": self.mutation_events,
            "rejected": self.rejected,
        }
        if self.health is not None:
            out.update({f"health_{k}": v for k, v in self.health.items()})
        return out


class ServeLoop:
    """Single-threaded, event-driven continuous-batching loop.

    The loop is deliberately free of threads and wall-time reads: time
    advances only through ``clock.sleep_until``, and with a VirtualClock the
    service model supplies each dispatch's duration — so a run is a pure
    function of (index, ladder, model, trace) and replays bit-identically.

    Scheduling policy (deterministic by construction):
      * the queue is kept in (deadline_t, arrival_t, rid) order — earliest
        deadline first, FIFO within a deadline class;
      * the loop waits for further arrivals only while the queue is smaller
        than the largest ladder batch AND the head request could still be
        served at its preferred ef after the wait (its "dispatch-by" point,
        ``deadline_t - service(max_batch bucket at preferred ef)``);
      * at dispatch, up to ``max_batch`` head requests form the batch, the
        batch axis pads up to the smallest fitting ladder rung, and the
        served ef is the largest rung that no member's dial forbids and the
        model predicts meets the tightest member deadline — else the next
        smaller rung (graceful degrade), else the ladder floor (served late,
        never rejected).
    """

    def __init__(self, index, *, ladder: Optional[BucketLadder] = None,
                 clock=None, k: int = 10, service_model=None,
                 executor: Optional[BucketExecutor] = None,
                 assert_invariants: bool = False,
                 registry=None, trace_ctx=None):
        self.ladder = ladder if ladder is not None else BucketLadder()
        self.clock = clock if clock is not None else VirtualClock()
        self.service_model = (service_model if service_model is not None
                              else LinearServiceModel())
        # registry/trace_ctx (repro.obs): None = the exact pre-observability
        # path, zero overhead.  Every registry record in this loop carries
        # loop-clock timestamps and values only — the loop still never reads
        # wall time (the registry's wall-clock span() is never used here;
        # tests pin the no-wall-time property with a time-module bomb).
        self.registry = registry
        self.executor = (executor if executor is not None
                         else BucketExecutor(index, self.ladder, k=k,
                                             trace_ctx=trace_ctx,
                                             registry=registry))
        self.k = self.executor.k
        # Opt-in safety net: re-check core/invariants.py after every applied
        # churn event (costs a host sweep per event; tests and debugging).
        self.assert_invariants = assert_invariants

    # -- policy helpers ----------------------------------------------------

    @staticmethod
    def _order(r: Request):
        return (r.deadline_t, r.arrival_t, r.rid)

    def _choose_ef(self, batch: Sequence[Request], bucket_batch: int,
                   now: float) -> Tuple[int, bool]:
        """Largest ladder ef within every member's dial that fits the
        tightest deadline; degrade down the ladder, floor as last resort."""
        pref = self.ladder.ef_pref(min(r.ef for r in batch))
        slack = min(r.deadline_t for r in batch) - now
        for ef in reversed([e for e in self.ladder.efs if e <= pref]):
            if self.service_model.service_s(Bucket(bucket_batch, ef)) <= slack:
                return ef, ef < pref
        return self.ladder.efs[0], True

    # -- the loop ----------------------------------------------------------

    def _apply_churn(self, churn_q: deque, now: float, applied: List) -> None:
        """Apply every due churn event (core/mutation.py) to the executor's
        MutableIndex.  Mutations land between dispatches only — a batch
        always sees a fully committed graph."""
        m = self.executor.mutable
        while churn_q and churn_q[0].t <= now:
            from repro.core.mutation import apply_churn_event

            ev = churn_q.popleft()
            applied.append(apply_churn_event(m, ev))
            if self.registry is not None:
                self.registry.counter(
                    "index_churn_events_total", "applied churn events",
                ).inc()
                self.registry.event("churn", now, kind=ev.kind)
                self._record_health(m)
            if self.assert_invariants:
                errs = m.check_invariants()
                if errs:
                    raise AssertionError(
                        "graph invariants violated after churn event "
                        f"{ev.kind!r} at t={ev.t}:\n" + "\n".join(errs)
                    )

    def _record_health(self, m) -> None:
        """Mirror MutableIndex.health() into registry gauges (post-churn
        index health: tombstone ratio, relink debt, dead edges, headroom)."""
        for key, val in m.health().items():
            self.registry.gauge(
                f"index_{key}", "MutableIndex.health() gauge",
            ).set(val)

    def _record_dispatch(self, bucket: Bucket, batch, now: float,
                         finish: float, degraded: bool) -> None:
        """Fold one dispatch + its responses into the registry.  All values
        derive from the loop clock and the already-built batch — no wall
        time, no extra device work."""
        reg = self.registry
        n = len(batch)
        reg.counter("serve_requests_total", "requests served").inc(n)
        reg.counter("serve_batches_total", "bucket dispatches").inc()
        if degraded:
            reg.counter(
                "serve_degraded_total",
                "dispatches served below the preferred ladder ef",
            ).inc()
        reg.histogram(
            "serve_coalesce_size", "requests coalesced per dispatch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512),
        ).observe(n)
        reg.histogram(
            "serve_occupancy", "live rows / bucket batch per dispatch",
            buckets=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0),
        ).observe(n / bucket.batch)
        wait_h = reg.histogram(
            "serve_queue_wait_seconds", "arrival -> dispatch (loop clock)",
        )
        lat_h = reg.histogram(
            "serve_latency_seconds", "arrival -> finish (loop clock)",
        )
        miss = reg.counter("serve_deadline_miss_total", "late responses")
        for r in batch:
            wait_h.observe(now - r.arrival_t)
            lat_h.observe(finish - r.arrival_t)
            if finish > r.deadline_t:
                miss.inc()
            reg.event(
                "response", finish, rid=r.rid,
                latency_s=finish - r.arrival_t,
                queue_wait_s=now - r.arrival_t,
                deadline_met=finish <= r.deadline_t,
            )
        ev = {"batch": bucket.batch, "ef": bucket.ef, "n": n,
              "degraded": degraded}
        walk = self.executor.last_walk
        if walk is not None:
            ev["band_hist"] = [int(v) for v in walk["band_hist"]]
            ev["hub_evals"] = walk["hub_evals"]
            ev["steps_mean"] = walk["steps_mean"]
        reg.event("dispatch", now, **ev)

    def run(self, requests: Iterable[Request], churn=None) -> ServeStats:
        """``churn`` (optional) is a ``core.mutation.ChurnTrace`` — or any
        sequence of ``ChurnEvent`` — replayed against the loop's
        MutableIndex interleaved with query traffic: events apply when the
        loop's clock passes their timestamps, never mid-batch, and events
        dated past the last response are drained at the end (the trace's
        turnover always completes).  Requires the executor to wrap a
        MutableIndex."""
        trace = sorted(requests, key=lambda r: (r.arrival_t, r.rid))
        d = self.executor.dim()
        for r in trace:
            if np.asarray(r.query).shape != (d,):
                raise ValueError(
                    f"request {r.rid}: query shape {np.asarray(r.query).shape}"
                    f" != ({d},)"
                )
        if not self.executor.warmed:
            self.executor.warmup()

        events = list(getattr(churn, "events", churn or ()))
        if events and self.executor.mutable is None:
            raise TypeError(
                "churn traces need a MutableIndex-backed executor "
                "(core.mutation.MutableIndex)"
            )
        churn_q = deque(sorted(events, key=lambda e: (e.t, e.kind)))
        applied: List[Dict] = []

        pending = deque(trace)
        queue: List[Request] = []
        responses: List[Response] = []
        batches: List[BatchRecord] = []
        max_b = self.ladder.max_batch

        while pending or queue:
            now = self.clock.now()
            self._apply_churn(churn_q, now, applied)
            while pending and pending[0].arrival_t <= now:
                queue.append(pending.popleft())
            if not queue:
                # Wake for whichever comes first: the next arrival or the
                # next churn event.
                t = pending[0].arrival_t
                if churn_q:
                    t = min(t, churn_q[0].t)
                self.clock.sleep_until(t)
                continue

            queue.sort(key=self._order)
            head = queue[0]
            next_arrival = pending[0].arrival_t if pending else None
            dispatch_by = head.deadline_t - self.service_model.service_s(
                Bucket(max_b, self.ladder.ef_pref(head.ef))
            )
            if (len(queue) < max_b and next_arrival is not None
                    and next_arrival <= dispatch_by and now < dispatch_by):
                # Coalesce: waiting for the next arrival cannot cost the
                # head its preferred service — sleep to the earliest of the
                # arrival, the head's dispatch-by point and the next churn
                # event (which must apply before the dispatch it precedes).
                t = min(next_arrival, dispatch_by)
                if churn_q:
                    t = min(t, churn_q[0].t)
                self.clock.sleep_until(max(t, now))
                continue

            batch = queue[:max_b]
            del queue[:len(batch)]
            bucket_batch = self.ladder.batch_for(len(batch))
            ef, degraded = self._choose_ef(batch, bucket_batch, now)
            bucket = Bucket(bucket_batch, ef)

            padded = np.zeros((bucket.batch, d), np.float32)
            for i, r in enumerate(batch):
                padded[i] = r.query
            valid = np.arange(bucket.batch) < len(batch)
            ids, scores, _ = self.executor.run(bucket, padded, valid)

            if self.clock.virtual:
                finish = now + self.service_model.service_s(bucket)
                self.clock.sleep_until(finish)
            else:
                finish = self.clock.now()

            for i, r in enumerate(batch):
                responses.append(Response(
                    rid=r.rid, ids=ids[i], scores=scores[i],
                    ef_request=r.ef, ef_served=ef, bucket=bucket,
                    arrival_t=r.arrival_t, dispatch_t=now, finish_t=finish,
                    deadline_t=r.deadline_t,
                    deadline_met=finish <= r.deadline_t,
                    degraded=degraded,
                ))
            batches.append(BatchRecord(
                seq=len(batches), dispatch_t=now, finish_t=finish,
                bucket=bucket, rids=tuple(r.rid for r in batch),
                ef_served=ef,
            ))
            if self.registry is not None:
                self._record_dispatch(bucket, batch, now, finish, degraded)

        # Drain churn events dated past the last response so the trace's
        # turnover completes even when traffic stops first.
        while churn_q:
            self.clock.sleep_until(churn_q[0].t)
            self._apply_churn(churn_q, self.clock.now(), applied)

        m = self.executor.mutable
        if self.registry is not None:
            self.registry.gauge(
                "serve_recompiles_warmup", "program builds during warmup",
            ).set(self.executor.recompiles_warmup)
            self.registry.gauge(
                "serve_recompiles_steady",
                "program builds after warmup (ladder regression if > 0)",
            ).set(self.executor.recompiles_steady)
        return ServeStats(
            responses=responses, batches=batches,
            recompiles_warmup=self.executor.recompiles_warmup,
            recompiles_steady=self.executor.recompiles_steady,
            mutation_events=len(applied),
            rejected=0,
            health=None if m is None else m.health(),
        )


# --------------------------------------------------------------------------
# Arrival sources
# --------------------------------------------------------------------------


def poisson_trace(
    queries: np.ndarray,
    *,
    rate_qps: float,
    seed: int = 0,
    ef: int = 64,
    classes: Sequence[str] = ("standard",),
    budgets: Optional[Dict[str, float]] = None,
    start_t: float = 0.0,
) -> List[Request]:
    """Open-loop Poisson arrivals: one request per query row, exponential
    inter-arrival gaps at ``rate_qps``, deadline classes sampled uniformly
    from ``classes``.  Pure ``numpy.random.default_rng(seed)`` — no wall
    clock anywhere, so a trace is reproducible byte-for-byte."""
    budgets = dict(DEADLINE_CLASSES if budgets is None else budgets)
    q = np.asarray(queries, np.float32)
    n = q.shape[0]
    rng = np.random.default_rng(seed)
    ts = start_t + np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    efs = np.broadcast_to(np.asarray(ef, np.int64), (n,))
    cls = rng.integers(0, len(classes), size=n)
    out = []
    for i in range(n):
        klass = classes[int(cls[i])]
        out.append(Request(
            rid=i, query=q[i], arrival_t=float(ts[i]),
            deadline_t=float(ts[i]) + budgets[klass],
            ef=int(efs[i]), klass=klass,
        ))
    return out
