"""Production mesh construction.

IMPORTANT: this module never touches jax device state at import time — the
mesh is built inside a function so the dry-run can set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh_compat  # noqa: F401  (re-export; the shim
# lives in repro.compat with the other jax-version fallbacks)


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = one pod of 256 chips (data x model);
    (2, 16, 16) = 2 pods / 512 chips (pod x data x model).  The pod axis
    carries only data parallelism + gradient all-reduce, so cross-pod (DCN)
    traffic is one gradient reduction per step."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(n_devices: int | None = None, axis: str = "model"):
    """Small CPU mesh for tests/examples (uses however many devices exist)."""
    n = n_devices or len(jax.devices())
    return make_mesh_compat((n,), (axis,))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def data_parallelism(mesh) -> int:
    p = 1
    for a in batch_axes_of(mesh):
        p *= mesh.shape[a]
    return p
