"""Synthetic datasets.

The paper's real datasets (Yahoo!Music, WordVector, ImageNet, Tiny5M) are not
redistributable offline; ``mips_dataset`` generates embedding sets whose NORM
PROFILE is engineered to match the paper's Figure-2 families, which is the
property all of the paper's analyses key on:

  gaussian       — iid N(0,1/d): tight chi-like norm distribution (Tiny5M /
                   Yahoo!Music shape: most items close to max norm)
  lognormal      — heavy right tail (WordVector/ImageNet shape, large TF)
  shifted(+c)    — ImageNet-A/-B transform of §5: add c to every Euclidean
                   norm without changing direction (TF shrinks as c grows)
"""
from __future__ import annotations

import numpy as np


def mips_dataset(
    n: int,
    d: int,
    profile: str = "gaussian",
    seed: int = 0,
    shift: float = 0.0,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) / np.sqrt(d)
    if profile == "gaussian":
        pass
    elif profile == "lognormal":
        scale = rng.lognormal(mean=0.0, sigma=0.6, size=(n, 1)).astype(np.float32)
        x = x * scale
    elif profile == "uniform_norm":
        target = rng.uniform(0.2, 1.0, size=(n, 1)).astype(np.float32)
        x = x / np.linalg.norm(x, axis=1, keepdims=True) * target
    else:
        raise ValueError(profile)
    if shift != 0.0:
        norms = np.linalg.norm(x, axis=1, keepdims=True)
        x = x * (norms + shift) / np.maximum(norms, 1e-12)
    return x


def mips_queries(n: int, d: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)) / np.sqrt(d)).astype(np.float32)


class SyntheticLMStream:
    """Deterministic, resumable token stream: batch_at(step) is a pure
    function of (seed, step) — the pipeline state in a checkpoint is just the
    step counter."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) + step)
        toks = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1))
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class SyntheticClickStream:
    """CTR/click batches for the recsys archs (same determinism contract)."""

    def __init__(self, n_items: int, batch: int, seq: int, n_sparse: int = 26,
                 n_dense: int = 13, seed: int = 0):
        self.n_items, self.batch, self.seq = n_items, batch, seq
        self.n_sparse, self.n_dense, self.seed = n_sparse, n_dense, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) + step)
        b, s = self.batch, self.seq
        hist = rng.integers(0, self.n_items, size=(b, s)).astype(np.int32)
        # ragged histories: mask a random prefix per row
        lengths = rng.integers(1, s + 1, size=(b, 1))
        hist = np.where(np.arange(s)[None, :] < lengths, hist, -1)
        return {
            "hist": hist,
            "pos": rng.integers(0, self.n_items, size=(b, s)).astype(np.int32),
            "neg": rng.integers(0, self.n_items, size=(b, s, 4)).astype(np.int32),
            "target": rng.integers(0, self.n_items, size=(b,)).astype(np.int32),
            "labels": rng.integers(0, 2, size=(b,)).astype(np.float32),
            "aux_neg": rng.integers(0, self.n_items, size=(b, s)).astype(np.int32),
            "dense": rng.normal(size=(b, self.n_dense)).astype(np.float32),
            "sparse": rng.integers(0, self.n_items, size=(b, self.n_sparse)).astype(np.int32),
        }
