from repro.data.synthetic import (
    SyntheticClickStream,
    SyntheticLMStream,
    mips_dataset,
    mips_queries,
)
