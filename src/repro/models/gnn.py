"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) — encode-process-decode GNN.

Message passing is implemented with ``jax.ops.segment_sum`` over an
edge-index -> node scatter (JAX has no sparse SpMM path worth using here;
the segment machinery IS the system, per the assignment note).

Distribution (DESIGN.md §5): edges AND node states are sharded over ALL mesh
axes (flattened "pod" x "data" x "model").  Per processor layer, each shard
  1. all-gathers the node-state shard into a transient full [N, d] block,
  2. runs the edge MLP + local segment_sum into a full-size partial aggregate,
  3. reduce-scatters the partials back to the node owner shards,
  4. updates its node-state shard with the node MLP.
The resident node state is [N/P, d] (ZeRO-style — 2.45M-node ogb_products
would not fit replicated through 15 layers of autodiff); the transient
gather + scatter move the same bytes a psum would, so the collective term is
unchanged but the memory term drops by P.  The AG/RS pair of [N, d_hidden]
per layer is the dominant collective for the big-graph shapes — it is the
collective-bound roofline cell and a §Perf hillclimb target.

Four shape regimes share this code path:
  full-batch small/large   — edges as given
  sampled minibatch        — padded subgraph from data/sampler.py (fanout)
  batched small graphs     — disjoint union (block-diagonal edge index)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.layers import _dense_init

ALL_AXES = ("pod", "data", "model")


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2       # hidden layers per MLP (paper: 2)
    d_feat: int = 128         # input node-feature dim
    d_edge: int = 4           # input edge-feature dim (>=1; synthetic if absent)
    out_dim: int = 3          # decoded per-node output (e.g. acceleration)
    aggregator: str = "sum"
    dtype: Any = jnp.float32
    remat: bool = True


# ---------------------------------------------------------------------------
# MLP + LayerNorm block (MeshGraphNet uses LN after every MLP)
# ---------------------------------------------------------------------------


def _mlp_ln_init(key, d_in, d_hidden, d_out, n_hidden, dtype, ln=True):
    dims = [d_in] + [d_hidden] * n_hidden + [d_out]
    ks = jax.random.split(key, len(dims) - 1)
    p = {
        "w": [_dense_init(ks[i], (dims[i], dims[i + 1]), dtype) for i in range(len(dims) - 1)],
        "b": [jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)],
    }
    if ln:
        p["ln_g"] = jnp.ones((d_out,), dtype)
        p["ln_b"] = jnp.zeros((d_out,), dtype)
    return p


def _mlp_ln(p, x):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1:
            x = jax.nn.relu(x)
    if "ln_g" in p:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["ln_g"] + p["ln_b"]
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_params(key, cfg: GNNConfig):
    k1, k2, k3, kl = jax.random.split(key, 4)
    h, m = cfg.d_hidden, cfg.mlp_layers
    layer_keys = jax.random.split(kl, cfg.n_layers * 2).reshape(cfg.n_layers, 2, *kl.shape)

    def proc_init(ks):
        return {
            # edge MLP input: [e, h_src, h_dst]
            "edge": _mlp_ln_init(ks[0], 3 * h, h, h, m, cfg.dtype),
            # node MLP input: [h, agg(e)]
            "node": _mlp_ln_init(ks[1], 2 * h, h, h, m, cfg.dtype),
        }

    params = {
        "node_enc": _mlp_ln_init(k1, cfg.d_feat, h, h, m, cfg.dtype),
        "edge_enc": _mlp_ln_init(k2, cfg.d_edge, h, h, m, cfg.dtype),
        "proc": jax.vmap(proc_init)(layer_keys),
        "dec": _mlp_ln_init(k3, h, h, cfg.out_dim, m, cfg.dtype, ln=False),
    }
    return params


def init(key, cfg: GNNConfig):
    return _init_params(key, cfg), specs(cfg)


def specs(cfg: GNNConfig):
    """All GNN parameters are tiny (~MB) — replicated; state/edges shard."""
    rep = lambda p: jax.tree.map(lambda _: P(), p)
    dummy = jax.eval_shape(lambda k: _init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree.map(lambda _: P(), dummy)


def data_specs(axes=ALL_AXES):
    """Shardings for the graph tensors: everything row-sharded over every
    mesh axis (node and edge counts are padded to multiples of the device
    count by the config layer)."""
    a = tuple(axes)
    return {
        "node_feat": P(a, None),
        "edge_feat": P(a, None),
        "src": P(a),
        "dst": P(a),
        "targets": P(a, None),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _aggregate(e, dst, n_nodes, aggregator="sum"):
    seg = jnp.where(dst >= 0, dst, n_nodes)
    if aggregator == "sum":
        agg = jax.ops.segment_sum(e, seg, num_segments=n_nodes + 1)
    elif aggregator == "max":
        agg = jax.ops.segment_max(e, seg, num_segments=n_nodes + 1)
    else:
        raise ValueError(aggregator)
    return agg[:-1]


def _proc_layer_local(lp, hn, e, src, dst, aggregator):
    """One processor layer on a (possibly local) edge block; returns the new
    edge block and the PARTIAL node aggregate (caller psums + updates)."""
    safe_src = jnp.maximum(src, 0)
    safe_dst = jnp.maximum(dst, 0)
    msg_in = jnp.concatenate([e, hn[safe_src], hn[safe_dst]], axis=-1)
    e_new = e + _mlp_ln(lp["edge"], msg_in)
    e_new = jnp.where((src >= 0)[:, None], e_new, 0)
    agg = _aggregate(e_new, dst, hn.shape[0], aggregator)
    return e_new, agg


def forward(params, graph, cfg: GNNConfig, mesh: Optional[jax.sharding.Mesh] = None):
    """graph = {node_feat [N, d_feat], edge_feat [E, d_edge],
    src [E] int32, dst [E] int32 (-1 padding)} -> node outputs [N, out_dim].

    With a mesh: node_feat/edge tensors arrive row-sharded (data_specs());
    encoder/decoder MLPs are row-parallel under plain GSPMD, the message-
    passing layers run in shard_map with the gather/scatter schedule in the
    module docstring.  N and E must be divisible by the device count.
    """
    hn = _mlp_ln(params["node_enc"], graph["node_feat"].astype(cfg.dtype))
    e = _mlp_ln(params["edge_enc"], graph["edge_feat"].astype(cfg.dtype))
    src, dst = graph["src"], graph["dst"]

    use_shard_map = mesh is not None and mesh.devices.size > 1
    axes = tuple(a for a in ALL_AXES if mesh is not None and a in mesh.axis_names)

    def layer(hn, e, lp):
        if use_shard_map:
            def body(lp, hn_blk, e_blk, src_blk, dst_blk):
                hn_full = jax.lax.all_gather(hn_blk, axes, axis=0, tiled=True)
                e_new, agg = _proc_layer_local(
                    lp, hn_full, e_blk, src_blk, dst_blk, cfg.aggregator
                )
                agg_blk = jax.lax.psum_scatter(
                    agg, axes, scatter_dimension=0, tiled=True
                )
                hn_new = hn_blk + _mlp_ln(
                    lp["node"], jnp.concatenate([hn_blk, agg_blk], axis=-1)
                )
                return hn_new, e_new

            hn_new, e_new = shard_map(
                body,
                mesh=mesh,
                in_specs=(
                    jax.tree.map(lambda _: P(), lp),
                    P(axes, None),
                    P(axes, None),
                    P(axes),
                    P(axes),
                ),
                out_specs=(P(axes, None), P(axes, None)),
                check_vma=False,
            )(lp, hn, e, src, dst)
        else:
            e_new, agg = _proc_layer_local(lp, hn, e, src, dst, cfg.aggregator)
            hn_new = hn + _mlp_ln(lp["node"], jnp.concatenate([hn, agg], axis=-1))
        return hn_new, e_new

    # scan over processor layers (edge state is threaded through the carry);
    # remat so backward recomputes the [N, d] all-gathers instead of saving
    # 15 of them (19.8 -> ~2 GiB temp on ogb_products)
    def scan_body(carry, lp):
        hn, e = carry
        hn2, e2 = layer(hn, e, lp)
        return (hn2, e2), None

    body = jax.checkpoint(scan_body) if cfg.remat else scan_body
    (hn, e), _ = jax.lax.scan(body, (hn, e), params["proc"])
    return _mlp_ln(params["dec"], hn)


def mse_loss(params, graph, cfg: GNNConfig, mesh=None):
    out = forward(params, graph, cfg, mesh)
    return jnp.mean((out - graph["targets"].astype(out.dtype)) ** 2)
