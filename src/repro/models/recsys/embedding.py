"""EmbeddingBag — JAX has no native one; this take/segment_sum implementation
IS part of the system (assignment note).

Two layouts:
  * padded bags  [B, L] int32 (-1 pad)    -> masked take + sum/mean
  * ragged bags  flat_ids [T] + offsets [B+1] -> take + segment_sum

Tables shard by ROW over the ``model`` axis (P("model", None) /
P(None, "model", None) for stacked field tables); lookups over row-sharded
tables lower to masked local gathers + an all-reduce combine under GSPMD —
the collective term of the recsys roofline cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"


def table_spec(stacked: bool = False):
    return P(None, MODEL_AXIS, None) if stacked else P(MODEL_AXIS, None)


def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "sum") -> jax.Array:
    """table [V, d]; ids [..., L] int32, -1 = padding -> [..., d]."""
    safe = jnp.maximum(ids, 0)
    vecs = jnp.take(table, safe, axis=0)              # [..., L, d]
    mask = (ids >= 0)[..., None].astype(vecs.dtype)
    s = jnp.sum(vecs * mask, axis=-2)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(mask, axis=-2), 1.0)
        return s / cnt
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jax.Array, flat_ids: jax.Array, offsets: jax.Array, mode: str = "sum"
) -> jax.Array:
    """table [V, d]; flat_ids [T]; offsets [B+1] -> [B, d] (torch
    EmbeddingBag semantics via take + segment_sum)."""
    b = offsets.shape[0] - 1
    t = flat_ids.shape[0]
    # bag id of every flat element: count of offsets <= position
    pos = jnp.arange(t)
    seg = jnp.searchsorted(offsets[1:], pos, side="right").astype(jnp.int32)
    vecs = jnp.take(table, jnp.maximum(flat_ids, 0), axis=0)
    vecs = vecs * (flat_ids >= 0)[:, None].astype(vecs.dtype)
    s = jax.ops.segment_sum(vecs, seg, num_segments=b)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            (flat_ids >= 0).astype(vecs.dtype), seg, num_segments=b
        )
        return s / jnp.maximum(cnt, 1.0)[:, None]
    raise ValueError(mode)


def multi_table_lookup(tables: jax.Array, ids: jax.Array) -> jax.Array:
    """tables [F, V, d]; ids [B, F] single-hot per field -> [B, F, d]."""
    f = tables.shape[0]
    return tables[jnp.arange(f)[None, :], jnp.maximum(ids, 0)]
