"""SASRec (Kang & McAuley, arXiv:1808.09781) — self-attentive sequential
recommendation.  embed_dim=50, 2 blocks, 1 head, seq_len=50.

The item tower output is a user embedding; serving is MIPS over the item
embedding table — the ip-NSW+ integration point (`retrieval_cand`).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init
from repro.models.recsys.embedding import table_spec


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_items: int = 1_000_000
    dropout: float = 0.0              # inference framework: no dropout
    dtype: Any = jnp.float32


def _init_params(key, cfg: SASRecConfig):
    d = cfg.embed_dim
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 6)
        blocks.append(
            {
                "wq": _dense_init(kb[0], (d, d), cfg.dtype),
                "wk": _dense_init(kb[1], (d, d), cfg.dtype),
                "wv": _dense_init(kb[2], (d, d), cfg.dtype),
                "w1": _dense_init(kb[3], (d, d), cfg.dtype),
                "w2": _dense_init(kb[4], (d, d), cfg.dtype),
                "ln1": jnp.ones((d,), cfg.dtype),
                "ln2": jnp.ones((d,), cfg.dtype),
            }
        )
    params = {
        "item_emb": (
            jax.random.normal(ks[0], (cfg.n_items, d), jnp.float32) * d**-0.5
        ).astype(cfg.dtype),
        "pos_emb": (
            jax.random.normal(ks[1], (cfg.seq_len, d), jnp.float32) * d**-0.5
        ).astype(cfg.dtype),
        "blocks": blocks,
    }
    return params


def init(key, cfg: SASRecConfig):
    return _init_params(key, cfg), specs(cfg)


def specs(cfg: SASRecConfig):
    dummy = jax.eval_shape(lambda k: _init_params(k, cfg), jax.random.PRNGKey(0))
    s = jax.tree.map(lambda _: P(), dummy)
    s["item_emb"] = table_spec()
    return s


def _ln(x, g, eps=1e-6):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def user_tower(params, hist, cfg: SASRecConfig):
    """hist [B, S] int32 item ids (-1 pad) -> seq repr [B, S, d]."""
    b, s = hist.shape
    mask = hist >= 0
    x = jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0)
    x = x * cfg.embed_dim**0.5 + params["pos_emb"][None, :s]
    x = x * mask[..., None].astype(x.dtype)

    causal = jnp.tril(jnp.ones((s, s), bool))
    attn_mask = causal[None] & mask[:, None, :]

    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        logits = jnp.einsum(
            "bsd,btd->bst", q, k, preferred_element_type=jnp.float32
        ) / cfg.embed_dim**0.5
        logits = jnp.where(attn_mask, logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        # rows with no valid key produce NaN-free zeros
        p = jnp.where(attn_mask.any(-1, keepdims=True), p, 0.0).astype(x.dtype)
        x = x + jnp.einsum("bst,btd->bsd", p, v)
        h2 = _ln(x, blk["ln2"])
        x = x + jax.nn.relu(h2 @ blk["w1"]) @ blk["w2"]
    return x * mask[..., None].astype(x.dtype)


def user_embedding(params, hist, cfg: SASRecConfig):
    """Last valid position's representation [B, d]."""
    reps = user_tower(params, hist, cfg)
    lengths = jnp.maximum(jnp.sum(hist >= 0, axis=1) - 1, 0)
    return jnp.take_along_axis(reps, lengths[:, None, None], axis=1)[:, 0]


def sampled_softmax_loss(params, batch, cfg: SASRecConfig):
    """batch = {hist [B, S], pos [B, S], neg [B, S, n_neg]} — per-position
    next-item prediction (paper's BCE generalized to n_neg negatives)."""
    reps = user_tower(params, batch["hist"], cfg)                 # [B, S, d]
    emb = params["item_emb"]
    pos_e = jnp.take(emb, jnp.maximum(batch["pos"], 0), axis=0)
    neg_e = jnp.take(emb, jnp.maximum(batch["neg"], 0), axis=0)
    pos_s = jnp.sum(reps * pos_e, -1)                             # [B, S]
    neg_s = jnp.einsum("bsd,bsnd->bsn", reps, neg_e)
    valid = (batch["pos"] >= 0).astype(jnp.float32)
    loss = -jax.nn.log_sigmoid(pos_s) - jnp.sum(
        jnp.log1p(-jax.nn.sigmoid(neg_s) + 1e-7), axis=-1
    )
    return jnp.sum(loss * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def retrieval_scores(params, hist, cfg: SASRecConfig, candidates=None):
    """MIPS over the item table (or explicit candidate rows) — the exact
    scoring path of `retrieval_cand`; graph-index serving uses
    core.IpNSWPlus over ``params["item_emb"]`` instead."""
    u = user_embedding(params, hist, cfg)                        # [B, d]
    items = params["item_emb"] if candidates is None else candidates
    return jnp.einsum(
        "bd,nd->bn", u, items, preferred_element_type=jnp.float32
    )
