"""DIEN (Zhou et al., arXiv:1809.03672) — interest evolution with
GRU + AUGRU (attention-gated GRU) over the behavior sequence.

embed_dim=18, seq_len=100, gru_dim=108, MLP 200-80 -> CTR logit.
Includes the paper's auxiliary next-behavior loss on the first GRU's states.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init, dense_stack, dense_stack_init
from repro.models.recsys.embedding import table_spec


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple = (200, 80)
    n_items: int = 1_000_000
    aux_weight: float = 0.5
    dtype: Any = jnp.float32


def _gru_init(key, d_in, d_h, dtype):
    ks = jax.random.split(key, 3)
    mk = lambda k: {
        "wx": _dense_init(k, (d_in, d_h), dtype),
        "wh": _dense_init(jax.random.fold_in(k, 1), (d_h, d_h), dtype),
        "b": jnp.zeros((d_h,), dtype),
    }
    return {"r": mk(ks[0]), "z": mk(ks[1]), "h": mk(ks[2])}


def _gru_gates(p, x, h):
    lin = lambda g, a, b: a @ g["wx"] + b @ g["wh"] + g["b"]
    r = jax.nn.sigmoid(lin(p["r"], x, h))
    z = jax.nn.sigmoid(lin(p["z"], x, h))
    hh = jnp.tanh(x @ p["h"]["wx"] + (r * h) @ p["h"]["wh"] + p["h"]["b"])
    return z, hh


def _init_params(key, cfg: DIENConfig):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, g = cfg.embed_dim, cfg.gru_dim
    # final features: [h_T (g), target (d), h_T*?: interaction h_T . proj(target)]
    mlp_in = g + d
    mlp, _ = dense_stack_init(k4, [mlp_in, *cfg.mlp, 1], cfg.dtype)
    params = {
        "item_emb": (
            jax.random.normal(k1, (cfg.n_items, d), jnp.float32) * d**-0.5
        ).astype(cfg.dtype),
        "gru1": _gru_init(k2, d, g, cfg.dtype),
        "augru": _gru_init(k3, g, g, cfg.dtype),
        "attn_w": _dense_init(k5, (d, g), cfg.dtype),
        "mlp": mlp,
    }
    return params


def init(key, cfg: DIENConfig):
    return _init_params(key, cfg), specs(cfg)


def specs(cfg: DIENConfig):
    dummy = jax.eval_shape(lambda k: _init_params(k, cfg), jax.random.PRNGKey(0))
    s = jax.tree.map(lambda _: P(), dummy)
    s["item_emb"] = table_spec()
    return s


def _run_gru(p, xs, mask, d_h):
    """xs [B, S, d]; mask [B, S] -> states [B, S, d_h]."""
    b = xs.shape[0]

    def step(h, args):
        x, m = args
        z, hh = _gru_gates(p, x, h)
        h_new = (1.0 - z) * h + z * hh
        h_new = jnp.where(m[:, None], h_new, h)
        return h_new, h_new

    h0 = jnp.zeros((b, d_h), xs.dtype)
    _, states = jax.lax.scan(
        step, h0, (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(mask, 1, 0))
    )
    return jnp.moveaxis(states, 0, 1)


def _run_augru(p, xs, att, mask, d_h):
    """AUGRU: update gate scaled by attention score a_t."""
    b = xs.shape[0]

    def step(h, args):
        x, a, m = args
        z, hh = _gru_gates(p, x, h)
        z = z * a[:, None]
        h_new = (1.0 - z) * h + z * hh
        h_new = jnp.where(m[:, None], h_new, h)
        return h_new, None

    h0 = jnp.zeros((b, d_h), xs.dtype)
    h, _ = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(att, 1, 0), jnp.moveaxis(mask, 1, 0)),
    )
    return h


def forward(params, batch, cfg: DIENConfig):
    """batch = {hist [B, S] int32 (-1 pad), target [B] int32} -> (logit [B],
    gru1 states [B, S, g]) — states returned for the auxiliary loss."""
    hist, target = batch["hist"], batch["target"]
    mask = hist >= 0
    e = jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0)
    te = jnp.take(params["item_emb"], jnp.maximum(target, 0), axis=0)  # [B, d]

    states = _run_gru(params["gru1"], e, mask, cfg.gru_dim)            # [B, S, g]

    att_logits = jnp.einsum("bsd,bd->bs", states @ params["attn_w"].T, te)
    att_logits = jnp.where(mask, att_logits, -jnp.inf)
    att = jax.nn.softmax(att_logits, axis=-1)
    att = jnp.where(mask, att, 0.0)

    h_final = _run_augru(params["augru"], states, att, mask, cfg.gru_dim)

    feats = jnp.concatenate([h_final, te], axis=-1)
    logit = dense_stack(params["mlp"], feats)[:, 0]
    return logit, states


def bce_loss(params, batch, cfg: DIENConfig):
    """Main CTR loss + DIEN auxiliary next-behavior loss.

    batch needs: hist, target, labels [B], aux_neg [B, S] (negative items)."""
    logit, states = forward(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    main = jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )

    hist = batch["hist"]
    mask = (hist >= 0)[:, 1:]
    e_next = jnp.take(
        params["item_emb"], jnp.maximum(hist[:, 1:], 0), axis=0
    )
    e_neg = jnp.take(
        params["item_emb"], jnp.maximum(batch["aux_neg"][:, 1:], 0), axis=0
    )
    h = states[:, :-1] @ params["attn_w"].T              # project g -> d
    pos_s = jnp.sum(h * e_next, -1)
    neg_s = jnp.sum(h * e_neg, -1)
    aux = -(jax.nn.log_sigmoid(pos_s) + jnp.log1p(-jax.nn.sigmoid(neg_s) + 1e-7))
    aux = jnp.sum(aux * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return main + cfg.aux_weight * aux


def retrieval_scores(params, hist, cfg: DIENConfig, candidates=None):
    """User vector = projected final interest state; MIPS over items."""
    mask = hist >= 0
    e = jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0)
    states = _run_gru(params["gru1"], e, mask, cfg.gru_dim)
    lengths = jnp.maximum(jnp.sum(mask, axis=1) - 1, 0)
    h_last = jnp.take_along_axis(states, lengths[:, None, None], axis=1)[:, 0]
    u = h_last @ params["attn_w"].T                      # [B, d]
    items = params["item_emb"] if candidates is None else candidates
    return jnp.einsum("bd,nd->bn", u, items, preferred_element_type=jnp.float32)
