"""DLRM (Naumov et al., arXiv:1906.00091), RM2-scale config.

dense [B, 13] -> bottom MLP -> [B, 64]
sparse [B, 26] -> 26 embedding tables (row-sharded over ``model``) -> [B, 26, 64]
dot interaction over the 27 vectors -> 351 pairwise dots + bottom copy
top MLP -> CTR logit.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_stack, dense_stack_init
from repro.models.recsys.embedding import multi_table_lookup, table_spec


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    n_rows: int = 1_000_000           # rows per sparse table
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)
    dtype: Any = jnp.float32

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def _init_params(key, cfg: DLRMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    tables = (
        jax.random.normal(k1, (cfg.n_sparse, cfg.n_rows, cfg.embed_dim), jnp.float32)
        * cfg.n_rows**-0.25
    ).astype(cfg.dtype)
    bot, _ = dense_stack_init(k2, [cfg.n_dense, *cfg.bot_mlp], cfg.dtype)
    top, _ = dense_stack_init(
        k3, [cfg.n_interact + cfg.embed_dim, *cfg.top_mlp], cfg.dtype
    )
    params = {"tables": tables, "bot": bot, "top": top}
    return params


def init(key, cfg: DLRMConfig):
    return _init_params(key, cfg), specs(cfg)


def specs(cfg: DLRMConfig):
    dummy = jax.eval_shape(lambda k: _init_params(k, cfg), jax.random.PRNGKey(0))
    s = jax.tree.map(lambda _: P(), dummy)
    s["tables"] = table_spec(stacked=True)
    return s


def forward(params, batch, cfg: DLRMConfig):
    """batch = {dense [B, 13] f32, sparse [B, 26] int32} -> logits [B]."""
    b = batch["dense"].shape[0]
    bot = dense_stack(params["bot"], batch["dense"].astype(cfg.dtype), final_act=True)
    emb = multi_table_lookup(params["tables"], batch["sparse"])  # [B, 26, d]
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)      # [B, 27, d]

    # dot interaction: lower triangle of feats @ feats^T
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.tril_indices(f, k=-1)
    inter = z[:, iu, ju]                                          # [B, 351]

    top_in = jnp.concatenate([bot, inter], axis=-1)
    logit = dense_stack(params["top"], top_in)
    return logit[:, 0]


def bce_loss(params, batch, cfg: DLRMConfig):
    logits = forward(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
