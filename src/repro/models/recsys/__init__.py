"""Recommender architectures: huge-embedding-table models whose serving
stage is the paper's MIPS problem (ip-NSW+ integration point)."""
from repro.models.recsys.embedding import (
    embedding_bag,
    embedding_bag_ragged,
    multi_table_lookup,
)
