"""MIND (Li et al., arXiv:1904.08030) — multi-interest network with dynamic
(B2I capsule) routing.

A user's behavior sequence is routed into ``n_interests`` capsules; serving
scores an item by the MAX inner product over interests — i.e. every user
issues ``n_interests`` MIPS queries, the paper's batched-query case for the
ip-NSW+ index.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init
from repro.models.recsys.embedding import table_spec


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    n_items: int = 1_000_000
    label_pow: float = 2.0            # label-aware attention exponent
    dtype: Any = jnp.float32


def _init_params(key, cfg: MINDConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "item_emb": (
            jax.random.normal(k1, (cfg.n_items, cfg.embed_dim), jnp.float32)
            * cfg.embed_dim**-0.5
        ).astype(cfg.dtype),
        "bilinear": _dense_init(k2, (cfg.embed_dim, cfg.embed_dim), cfg.dtype),
        # fixed (non-trained in-paper) routing-logit init; kept as a param so
        # checkpoints are self-contained
        "routing_init": (
            jax.random.normal(k3, (cfg.seq_len, cfg.n_interests), jnp.float32)
        ).astype(cfg.dtype),
    }
    return params


def init(key, cfg: MINDConfig):
    return _init_params(key, cfg), specs(cfg)


def specs(cfg: MINDConfig):
    dummy = jax.eval_shape(lambda k: _init_params(k, cfg), jax.random.PRNGKey(0))
    s = jax.tree.map(lambda _: P(), dummy)
    s["item_emb"] = table_spec()
    return s


def _squash(z, eps=1e-9):
    n2 = jnp.sum(z * z, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * z * jax.lax.rsqrt(n2 + eps)


def interest_capsules(params, hist, cfg: MINDConfig):
    """hist [B, S] (-1 pad) -> interests [B, K, d]."""
    b, s = hist.shape
    mask = (hist >= 0).astype(jnp.float32)
    e = jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0)  # [B, S, d]
    u_hat = jnp.einsum("bsd,de->bse", e, params["bilinear"])        # [B, S, d]
    u_hat = u_hat * mask[..., None]

    blog = jnp.broadcast_to(params["routing_init"][None, :s], (b, s, cfg.n_interests))

    def routing_iter(blog, _):
        w = jax.nn.softmax(blog, axis=-1)                            # over K
        w = w * mask[..., None]
        z = jnp.einsum("bsk,bsd->bkd", w, u_hat)                     # [B, K, d]
        u = _squash(z)
        blog_new = blog + jnp.einsum("bsd,bkd->bsk", u_hat, u)
        return blog_new, u

    blog, us = jax.lax.scan(routing_iter, blog, None, length=cfg.capsule_iters)
    return us[-1]                                                    # [B, K, d]


def label_aware_user(params, interests, target_emb, cfg: MINDConfig):
    """Label-aware attention (training): weight interests by (u_k . e_t)^p."""
    sc = jnp.einsum("bkd,bd->bk", interests, target_emb)
    w = jax.nn.softmax(cfg.label_pow * sc, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def sampled_softmax_loss(params, batch, cfg: MINDConfig):
    """batch = {hist [B, S], pos [B], neg [B, n_neg]}."""
    interests = interest_capsules(params, batch["hist"], cfg)
    emb = params["item_emb"]
    pos_e = jnp.take(emb, jnp.maximum(batch["pos"], 0), axis=0)      # [B, d]
    neg_e = jnp.take(emb, jnp.maximum(batch["neg"], 0), axis=0)      # [B, n, d]
    user = label_aware_user(params, interests, pos_e, cfg)
    pos_s = jnp.sum(user * pos_e, -1, keepdims=True)                 # [B, 1]
    neg_s = jnp.einsum("bd,bnd->bn", user, neg_e)
    logits = jnp.concatenate([pos_s, neg_s], axis=-1)
    return -jnp.mean(jax.nn.log_softmax(logits, axis=-1)[:, 0])


def retrieval_scores(params, hist, cfg: MINDConfig, candidates=None):
    """max-over-interests MIPS scores [B, N] — K MIPS queries per user."""
    interests = interest_capsules(params, hist, cfg)                 # [B, K, d]
    items = params["item_emb"] if candidates is None else candidates
    sc = jnp.einsum(
        "bkd,nd->bkn", interests, items, preferred_element_type=jnp.float32
    )
    return jnp.max(sc, axis=1)
