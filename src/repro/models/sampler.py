"""Uniform fanout neighbor sampler (GraphSAGE-style) for the
``minibatch_lg`` shape — a REAL sampler over a CSR graph, not a stub.

Host-side numpy (samplers are data-pipeline work, not accelerator work);
returns fixed-shape padded arrays so the GNN step stays jit-compiled:

  sample_subgraph(csr, seeds, fanouts) ->
    {node_feat-gatherable local ids, src, dst, n_nodes, n_edges}

Local relabeling: sampled nodes get contiguous local ids (seeds first), the
edge index is local, padding is -1.  Deterministic per (seed, step) via the
provided rng.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray   # [N+1]
    indices: np.ndarray  # [nnz]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1


def random_csr(n_nodes: int, avg_degree: int, rng: np.random.Generator) -> CSRGraph:
    """Synthetic power-law-ish CSR graph for tests/benchmarks."""
    deg = np.clip(
        rng.zipf(1.6, n_nodes) + avg_degree // 2, 1, 16 * avg_degree
    ).astype(np.int64)
    scale = (avg_degree * n_nodes) / max(deg.sum(), 1)
    deg = np.maximum((deg * scale).astype(np.int64), 1)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    indices = rng.integers(0, n_nodes, size=int(indptr[-1]), dtype=np.int64)
    return CSRGraph(indptr=indptr, indices=indices)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
    pad_to: tuple | None = None,
):
    """Layered uniform sampling.  Returns dict with local-id edge index.

    pad_to = (max_nodes, max_edges) fixes output shapes for jit."""
    seeds = np.asarray(seeds, dtype=np.int64)
    local_of = {int(s): i for i, s in enumerate(seeds)}
    nodes = list(seeds)
    src_l, dst_l = [], []
    frontier = seeds

    for fanout in fanouts:
        next_frontier = []
        for u in frontier:
            lo, hi = graph.indptr[u], graph.indptr[u + 1]
            nbrs = graph.indices[lo:hi]
            if len(nbrs) == 0:
                continue
            take = rng.choice(nbrs, size=min(fanout, len(nbrs)), replace=False)
            for v in take:
                v = int(v)
                if v not in local_of:
                    local_of[v] = len(nodes)
                    nodes.append(v)
                    next_frontier.append(v)
                # message v -> u (aggregate from sampled neighbor into seed)
                src_l.append(local_of[v])
                dst_l.append(local_of[int(u)])
        frontier = np.asarray(next_frontier, dtype=np.int64)

    node_ids = np.asarray(nodes, dtype=np.int64)
    src = np.asarray(src_l, dtype=np.int32)
    dst = np.asarray(dst_l, dtype=np.int32)

    if pad_to is not None:
        max_nodes, max_edges = pad_to
        assert len(node_ids) <= max_nodes and len(src) <= max_edges, (
            len(node_ids),
            len(src),
            pad_to,
        )
        node_ids = np.pad(node_ids, (0, max_nodes - len(node_ids)), constant_values=0)
        src = np.pad(src, (0, max_edges - len(src)), constant_values=-1)
        dst = np.pad(dst, (0, max_edges - len(dst)), constant_values=-1)

    return {
        "node_ids": node_ids,
        "src": src,
        "dst": dst,
        "n_nodes": len(nodes),
        "n_edges": len(src_l),
    }


def fanout_budget(batch_nodes: int, fanouts: Sequence[int]) -> tuple:
    """Worst-case (max_nodes, max_edges) for padding."""
    n, e, layer = batch_nodes, 0, batch_nodes
    for f in fanouts:
        e += layer * f
        layer = layer * f
        n += layer
    return n, e
