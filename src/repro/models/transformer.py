"""Dense + MoE decoder-only LM — the assigned-architecture substrate.

Layers are stacked [n_rep, P, ...] and applied with ``lax.scan`` over n_rep
(compile-time O(1) in depth); P = len(window_pattern) is the static
local/global attention cycle (gemma3: 5 sliding + 1 global).  Remat
(activation checkpointing) wraps each scan body.

Entry points (all pure; mesh passed explicitly for the MoE shard_map):
  init(key, cfg)                         -> (params, specs)
  forward(params, tokens, cfg, mesh)     -> (logits, aux_loss)
  lm_loss(params, batch, cfg, mesh)      -> scalar loss
  init_cache(cfg, batch, max_len)        -> per-pattern KV caches
  serve_prefill(params, tokens, cfg, ..) -> (logits, cache)
  serve_step(params, cache, token, off)  -> (logits, new cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M

BATCH_AXES = L.BATCH_AXES
MODEL_AXIS = L.MODEL_AXIS


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    # cycle of per-layer attention windows; None = global full attention.
    # gemma3: (W, W, W, W, W, None) — 5 local : 1 global.
    window_pattern: Tuple[Optional[int], ...] = (None,)
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_expert_split: int = 1   # store each expert as N ffn column-shards
    capacity_factor: float = 1.25
    tied_embed: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 512
    # Megatron-style sequence parallelism: shard the residual stream's seq
    # dim over "model" between blocks (activation memory / P; the AG/RS pair
    # it induces is the Megatron-SP schedule).  Applied when S >= 2048.
    seq_parallel: bool = True
    # Perf knobs: online-softmax attention + chunked cross-entropy keep the
    # fp32 score/logit matrices off HBM.
    flash: bool = True
    kv_chunk: int = 1024
    loss_chunk: int = 1024  # 0 = materialize full [B, S, V] logits

    @property
    def pattern_len(self) -> int:
        return len(self.window_pattern)

    @property
    def n_rep(self) -> int:
        assert self.n_layers % self.pattern_len == 0, (
            self.n_layers,
            self.window_pattern,
        )
        return self.n_layers // self.pattern_len

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def param_count(self) -> int:
        """Total parameters (for the roofline's 6*N*D term)."""
        attn = self.d_model * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
        if self.is_moe:
            ffn = self.moe_experts * 3 * self.d_model * self.d_ff
            ffn += self.d_model * self.moe_experts
        else:
            ffn = 3 * self.d_model * self.d_ff
        per_layer = attn + ffn + 2 * self.d_model
        embed = self.vocab * self.d_model * (1 if self.tied_embed else 2)
        return self.n_layers * per_layer + embed + self.d_model

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of moe_experts)."""
        if not self.is_moe:
            return self.param_count()
        attn = self.d_model * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
        ffn = self.moe_top_k * 3 * self.d_model * self.d_ff
        ffn += self.d_model * self.moe_experts
        per_layer = attn + ffn + 2 * self.d_model
        embed = self.vocab * self.d_model * (1 if self.tied_embed else 2)
        return self.n_layers * per_layer + embed + self.d_model


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: TransformerConfig):
    k1, k2 = jax.random.split(key)
    p = {}
    p["attn"], _ = L.attention_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, cfg.dtype
    )
    if cfg.is_moe:
        p["ffn"], _ = M.moe_init(
            k2, cfg.d_model, cfg.d_ff, cfg.moe_experts, cfg.dtype,
            expert_split=cfg.moe_expert_split,
        )
    else:
        p["ffn"], _ = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    p["ln1"], _ = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    p["ln2"], _ = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    return p


def _layer_specs(cfg: TransformerConfig):
    return {
        "attn": L.attention_specs(),
        "ffn": M.moe_specs() if cfg.is_moe else L.mlp_specs(),
        "ln1": P(None),
        "ln2": P(None),
    }


def init(key, cfg: TransformerConfig):
    ke, ku, kl = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, (cfg.n_rep, cfg.pattern_len))
    blocks = jax.vmap(jax.vmap(lambda k: _layer_init(k, cfg)))(layer_keys)
    params = {
        "embed": L._dense_init(ke, (cfg.vocab, cfg.d_model), cfg.dtype, scale=1.0),
        "blocks": blocks,
        "final_ln": L.rmsnorm_init(cfg.d_model, cfg.dtype)[0],
    }
    if not cfg.tied_embed:
        params["unembed"] = L._dense_init(ku, (cfg.d_model, cfg.vocab), cfg.dtype)
    return params, specs(cfg)


def specs(cfg: TransformerConfig):
    lay = _layer_specs(cfg)
    stacked = jax.tree.map(
        lambda s: P(None, None, *s),
        lay,
        is_leaf=lambda x: isinstance(x, P),
    )
    out = {
        "embed": P(MODEL_AXIS, None),
        "blocks": stacked,
        "final_ln": P(None),
    }
    if not cfg.tied_embed:
        out["unembed"] = P(None, MODEL_AXIS)
    return out


def abstract_params(cfg: TransformerConfig, key=None):
    """Parameter ShapeDtypeStructs without allocation (dry-run input)."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda kk: init(kk, cfg)[0], k)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(
    bp, x, positions, cfg, window, mesh, kv_cache=None, q_offset=0, res_spec=None
):
    h, new_kv = L.attention(
        bp["attn"],
        L.rmsnorm(x, bp["ln1"]),
        positions,
        n_kv=cfg.n_kv,
        window=window,
        kv_cache=kv_cache,
        q_offset=q_offset,
        rope_theta=cfg.rope_theta,
        chunk=cfg.attn_chunk,
        flash=cfg.flash,
        kv_chunk=cfg.kv_chunk,
    )
    x = x + h
    if res_spec is not None:
        # §Perf (H1): pin the residual boundary right at the partial-sum so
        # GSPMD emits a bf16 reduce-scatter here instead of hoisting the
        # fp32 convert above a full all-reduce.
        x = L.shard(x, *res_spec)
    h2 = L.rmsnorm(x, bp["ln2"])
    if cfg.is_moe:
        ff, aux = M.moe_apply(
            bp["ffn"],
            h2,
            n_experts=cfg.moe_experts,
            top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor,
            expert_split=cfg.moe_expert_split,
            mesh=mesh,
        )
    else:
        ff, aux = L.mlp(bp["ffn"], h2), jnp.float32(0.0)
    return x + ff, new_kv, aux


def forward(params, tokens, cfg: TransformerConfig, mesh=None):
    """tokens [B, S] -> (logits [B, S, V] fp32, aux_loss)."""
    b, s = tokens.shape
    sp = cfg.seq_parallel and s >= 2048
    res_spec = ("batch", MODEL_AXIS, None) if sp else ("batch", None, None)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = L.shard(x, *res_spec)
    positions = jnp.arange(s, dtype=jnp.int32)

    def rep_body(x, rep_params):
        aux_tot = jnp.float32(0.0)
        for p_i, window in enumerate(cfg.window_pattern):
            bp = jax.tree.map(lambda a: a[p_i], rep_params)
            x, _, aux = _block_apply(
                bp, x, positions, cfg, window, mesh, res_spec=res_spec
            )
            aux_tot += aux
        x = L.shard(x, *res_spec)  # saved scan carry: seq-parallel residuals
        return x, aux_tot

    body = jax.checkpoint(rep_body) if cfg.remat else rep_body
    x, auxes = jax.lax.scan(body, x, params["blocks"])

    x = L.rmsnorm(x, params["final_ln"])
    unembed = (
        params["embed"].T if cfg.tied_embed else params["unembed"]
    )
    logits = jnp.einsum(
        "bsd,dv->bsv", x, unembed, preferred_element_type=jnp.float32
    )
    logits = L.shard(logits, "batch", None, MODEL_AXIS)
    return logits, jnp.sum(auxes)


def hidden_states(params, tokens, cfg: TransformerConfig, mesh=None):
    """Forward up to (and incl.) the final norm: [B, S, d] + aux — used by
    the chunked loss so the [B, S, V] logits never materialize."""
    b, s = tokens.shape
    sp = cfg.seq_parallel and s >= 2048
    res_spec = ("batch", MODEL_AXIS, None) if sp else ("batch", None, None)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = L.shard(x, *res_spec)
    positions = jnp.arange(s, dtype=jnp.int32)

    def rep_body(x, rep_params):
        aux_tot = jnp.float32(0.0)
        for p_i, window in enumerate(cfg.window_pattern):
            bp = jax.tree.map(lambda a: a[p_i], rep_params)
            x, _, aux = _block_apply(
                bp, x, positions, cfg, window, mesh, res_spec=res_spec
            )
            aux_tot += aux
        x = L.shard(x, *res_spec)
        return x, aux_tot

    body = jax.checkpoint(rep_body) if cfg.remat else rep_body
    x, auxes = jax.lax.scan(body, x, params["blocks"])
    return L.rmsnorm(x, params["final_ln"]), jnp.sum(auxes)


def lm_loss(params, batch, cfg: TransformerConfig, mesh=None, aux_weight=0.01):
    """batch = {"tokens": [B, S] int32, "labels": [B, S] int32}.

    §Perf (loss_chunk > 0): the cross-entropy is computed in sequence chunks
    under remat, so the peak logits buffer is [B, chunk, V/model] instead of
    [B, S, V/model] (fp32) — the memory-term fix for the big-vocab archs.
    """
    b, s = batch["tokens"].shape
    unembed = params["embed"].T if cfg.tied_embed else params["unembed"]

    if cfg.loss_chunk and s % cfg.loss_chunk == 0 and s > cfg.loss_chunk:
        x, aux = hidden_states(params, batch["tokens"], cfg, mesh)
        n = s // cfg.loss_chunk
        xc = jnp.moveaxis(x.reshape(b, n, cfg.loss_chunk, -1), 1, 0)
        lc = jnp.moveaxis(
            batch["labels"].astype(jnp.int32).reshape(b, n, cfg.loss_chunk), 1, 0
        )

        @jax.checkpoint
        def chunk_nll(xi, li):
            logits = jnp.einsum(
                "bsd,dv->bsv", xi, unembed, preferred_element_type=jnp.float32
            )
            logits = L.shard(logits, "batch", None, MODEL_AXIS)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
            return jnp.sum(logz - gold)

        def body(tot, args):
            xi, li = args
            return tot + chunk_nll(xi, li), None

        tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
        nll = tot / (b * s)
    else:
        logits, aux = forward(params, batch["tokens"], cfg, mesh)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["labels"][..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux


# ---------------------------------------------------------------------------
# serving (prefill + decode with per-pattern KV caches)
# ---------------------------------------------------------------------------


def cache_len(cfg: TransformerConfig, window: Optional[int], max_len: int) -> int:
    return max_len if window is None else min(window, max_len)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    """Per-pattern-position KV caches: tuple over P of (k, v) stacked over
    n_rep — sliding-window layers allocate only ``window`` slots (the
    sub-quadratic memory path for long_500k)."""
    dtype = dtype or cfg.dtype
    caches = []
    for window in cfg.window_pattern:
        t = cache_len(cfg, window, max_len)
        shape = (cfg.n_rep, batch, t, cfg.n_kv, cfg.head_dim)
        caches.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
    return tuple(caches)


def cache_specs(cfg: TransformerConfig, batch=("data",), seq=(MODEL_AXIS,)):
    """Shardings for the cache [n_rep, B, T, kv, hd]: batch over the data
    axes; sequence over ``seq`` (flash-decoding style — XLA lowers the masked
    softmax-reduction over the sharded axis into partial reductions +
    all-reduce).  For batch-1 long-context decode pass batch=() and
    seq=(every axis,)."""
    b = tuple(batch) if batch else None
    s = tuple(seq) if seq else None
    return tuple(
        (P(None, b, s, None, None), P(None, b, s, None, None))
        for _ in cfg.window_pattern
    )


def serve_prefill(params, tokens, cfg: TransformerConfig, mesh=None, max_len=None):
    """Prefill: run the full prompt, materialize caches.  Returns
    (last-token logits [B, V], caches).  max_len >= S sizes the cache."""
    b, s = tokens.shape
    max_len = max_len or s
    sp = cfg.seq_parallel and s >= 2048
    res_spec = ("batch", MODEL_AXIS, None) if sp else ("batch", None, None)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = L.shard(x, *res_spec)
    positions = jnp.arange(s, dtype=jnp.int32)

    # prefill writes caches; scan collects per-rep (k, v) stacks
    def rep_body(x, rep_params):
        kvs = []
        for p_i, window in enumerate(cfg.window_pattern):
            bp = jax.tree.map(lambda a: a[p_i], rep_params)
            x, kv, _ = _block_apply(
                bp, x, positions, cfg, window, mesh, res_spec=res_spec
            )
            t = cache_len(cfg, window, max_len)
            k, v = kv
            if s >= t:
                k, v = k[:, s - t :], v[:, s - t :]
            else:
                pad = t - s
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kvs.append((k.astype(cfg.dtype), v.astype(cfg.dtype)))
        x = L.shard(x, *res_spec)
        return x, tuple(kvs)

    body = jax.checkpoint(rep_body) if cfg.remat else rep_body
    x, caches = jax.lax.scan(body, x, params["blocks"])

    x = L.rmsnorm(x[:, -1:], params["final_ln"])
    unembed = params["embed"].T if cfg.tied_embed else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed, preferred_element_type=jnp.float32)
    return logits[:, 0], caches


def serve_step(params, caches, token, q_offset, cfg: TransformerConfig, mesh=None):
    """One decode step.  token [B, 1] int32; q_offset [] int32 = absolute
    position of the new token.  Returns (logits [B, V], new caches).

    NOTE on ring caches: prefill stores the last ``t`` positions at their
    natural slots only when s % t == 0 (true for our power-of-two shapes);
    the serve example uses max_len % window == 0 accordingly.
    """
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)
    positions = jnp.full((1,), q_offset, jnp.int32)

    def rep_body(x, args):
        rep_params = args[0]
        rep_caches = args[1:]
        new_kvs = []
        for p_i, window in enumerate(cfg.window_pattern):
            bp = jax.tree.map(lambda a: a[p_i], rep_params)
            x, kv, _ = _block_apply(
                bp, x, positions, cfg, window, mesh,
                kv_cache=rep_caches[p_i], q_offset=q_offset,
            )
            new_kvs.append(kv)
        return x, tuple(new_kvs)

    x, new_caches = jax.lax.scan(rep_body, x, (params["blocks"],) + caches)

    x = L.rmsnorm(x, params["final_ln"])
    unembed = params["embed"].T if cfg.tied_embed else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed, preferred_element_type=jnp.float32)
    return logits[:, 0], new_caches
