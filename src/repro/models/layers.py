"""Transformer substrate layers — pure-functional (params are plain pytrees,
shardings are parallel pytrees of PartitionSpec built by ``specs_*`` helpers).

Conventions
  * params: nested dicts of jnp arrays; a layer's init returns (params, specs)
    where specs mirrors params with jax.sharding.PartitionSpec leaves.
  * mesh logical axes: "pod" x "data" (batch), "model" (tensor/expert).
  * compute dtype bf16, params stored bf16 (master-weightless; moments fp32 in
    the optimizer), fp32 for norms/softmax accumulation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"

# The batch ("data-parallel") axes depend on the mesh: ("pod", "data") on the
# multi-pod mesh, ("data",) on a single pod.  launch code sets this before
# tracing; the sentinel string "batch" in shard() calls resolves against it.
_BATCH_AXES = ("pod", "data")


def set_batch_axes(axes) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def batch_axes() -> tuple:
    return _BATCH_AXES


def set_batch_axes_for_mesh(mesh) -> None:
    set_batch_axes(tuple(a for a in mesh.axis_names if a != MODEL_AXIS))


def shard(x: jax.Array, *spec) -> jax.Array:
    """Activation sharding hint (no-op outside a mesh context).  The string
    "batch" resolves to the current batch axes.  In pure-FSDP mode (the
    "model" axis itself carries batch — §Perf iteration 3 for dense-LM
    training), standalone "model" constraints become None: there is no
    tensor-parallel activation axis."""
    fsdp = MODEL_AXIS in _BATCH_AXES
    resolved = []
    for s in spec:
        if s == "batch":
            resolved.append(_BATCH_AXES)
        elif fsdp and (s == MODEL_AXIS or (isinstance(s, tuple) and MODEL_AXIS in s)):
            resolved.append(None)
        else:
            resolved.append(s)
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except (ValueError, RuntimeError, TypeError, NameError):
        return x


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype), P(None)


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * g.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [..,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding-window causal), train + prefill + decode
# ---------------------------------------------------------------------------


def attention_specs():
    # 2D sharding: q heads + output-proj head axis are tensor-parallel
    # ("model"); the d_model dim is FSDP-sharded over "data" for storage
    # (GSPMD all-gathers just-in-time).  kv projections are small
    # (n_kv <= 8 < model parallelism): d over "data" only.
    return {
        "wq": P("data", MODEL_AXIS, None),
        "wk": P("data", None, None),
        "wv": P("data", None, None),
        "wo": P(MODEL_AXIS, None, "data"),
    }


def attention_init(key, d_model, n_heads, n_kv, head_dim, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d_model, n_heads, head_dim), dtype),
        "wk": _dense_init(ks[1], (d_model, n_kv, head_dim), dtype),
        "wv": _dense_init(ks[2], (d_model, n_kv, head_dim), dtype),
        "wo": _dense_init(ks[3], (n_heads, head_dim, d_model), dtype),
    }
    return params, attention_specs()


def _attend_block(qg, k, v, q_pos, k_pos, window: Optional[int]):
    """One (q-chunk x full-kv) attention block with masking.

    qg: [B, c, KV, G, hd]; k, v: [B, T, KV, hd]; q_pos: [c] absolute query
    positions; k_pos: [T] absolute key positions (-1 = invalid slot).
    """
    hd = qg.shape[-1]
    logits = jnp.einsum(
        "bsngh,btnh->bngst", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(hd).astype(jnp.float32)
    m = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] >= 0)
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(m[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bngst,btnh->bsngh", probs, v)


NEG_BIG = jnp.float32(-1e30)  # finite mask value (keeps flash stats NaN-free)


def _flash_mask(kp, q_pos, window):
    msk = (kp[None, :] <= q_pos[:, None]) & (kp[None, :] >= 0)
    if window is not None:
        msk &= kp[None, :] > q_pos[:, None] - window
    return msk


def _flash_bias(kp, q_pos, window):
    """Additive [c, ck] mask bias — a rank-2 add fuses into the logits
    matmul epilogue; a rank-6 jnp.where materializes a 100MB pred tensor
    per tile (§Perf iteration 4)."""
    return jnp.where(_flash_mask(kp, q_pos, window), 0.0, NEG_BIG).astype(
        jnp.float32
    )


def _flash_fwd_scan(qg, k, v, q_pos, k_pos, window, kv_chunk):
    """Returns (out [B,KV,G,c,hd] fp32, m, l) — m/l are the per-row softmax
    stats the backward recomputes tiles from."""
    b, c, kvh, g, hd = qg.shape
    t = k.shape[1]
    n = t // kv_chunk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, n, kv_chunk, kvh, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n, kv_chunk, kvh, hd), 1, 0)
    pc = k_pos.reshape(n, kv_chunk)

    m0 = jnp.full((b, kvh, g, c), NEG_BIG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, c), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, c, hd), jnp.float32)

    def body(carry, args):
        m, l, acc = carry
        kb, vb, kp = args
        logits = jnp.einsum(
            "bsngh,btnh->bngst", qg, kb, preferred_element_type=jnp.float32
        ) * scale                                            # [B,KV,G,c,ck]
        logits = logits + _flash_bias(kp, q_pos, window)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bngst,btnh->bngsh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _attend_flash(qg, k, v, q_pos, k_pos, window: Optional[int], kv_chunk: int):
    """Online-softmax (flash) attention — §Perf centerpiece for the LM cells:
    the [c, T] fp32 score matrix never materializes beyond a [c, kv_chunk]
    tile, and the CUSTOM BACKWARD recomputes tiles from the saved (m, l)
    softmax stats (FlashAttention backward) instead of letting scan-AD store
    per-chunk fp32 accumulators.

    qg: [B, c, KV, G, hd]; k, v: [B, T, KV, hd]; T % kv_chunk == 0.
    Returns [B, c, KV, G, hd] in v.dtype.
    """
    out, _, _ = _flash_fwd_scan(qg, k, v, q_pos, k_pos, window, kv_chunk)
    return jnp.moveaxis(out, 3, 1).astype(v.dtype)


def _attend_flash_fwd(qg, k, v, q_pos, k_pos, window, kv_chunk):
    out, m, l = _flash_fwd_scan(qg, k, v, q_pos, k_pos, window, kv_chunk)
    primal = jnp.moveaxis(out, 3, 1).astype(v.dtype)
    return primal, (qg, k, v, q_pos, k_pos, out, m, l)


def _attend_flash_bwd(window, kv_chunk, res, dout):
    qg, k, v, q_pos, k_pos, out, m, l = res
    b, c, kvh, g, hd = qg.shape
    t = k.shape[1]
    n = t // kv_chunk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    do = jnp.moveaxis(dout.astype(jnp.float32), 1, 3)        # [B,KV,G,c,hd]
    delta = jnp.sum(do * out, axis=-1)                       # [B,KV,G,c]
    l_safe = jnp.maximum(l, 1e-30)

    kc = jnp.moveaxis(k.reshape(b, n, kv_chunk, kvh, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n, kv_chunk, kvh, hd), 1, 0)
    pc = k_pos.reshape(n, kv_chunk)

    def body(dq, args):
        kb, vb, kp = args
        logits = jnp.einsum(
            "bsngh,btnh->bngst", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        logits = logits + _flash_bias(kp, q_pos, window)[None, None, None]
        p = jnp.exp(logits - m[..., None]) / l_safe[..., None]  # true softmax
        dp = jnp.einsum(
            "bngsh,btnh->bngst", do, vb, preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[..., None])                      # [B,KV,G,c,ck]
        dq = dq + jnp.einsum(
            "bngst,btnh->bsngh", ds.astype(kb.dtype), kb,
            preferred_element_type=jnp.float32,
        ) * scale
        dkb = jnp.einsum(
            "bngst,bsngh->btnh", ds.astype(qg.dtype), qg,
            preferred_element_type=jnp.float32,
        ) * scale
        dvb = jnp.einsum(
            "bngst,bngsh->btnh", p.astype(do.dtype), do,
            preferred_element_type=jnp.float32,
        )
        return dq, (dkb, dvb)

    dq0 = jnp.zeros((b, c, kvh, g, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, pc))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, t, kvh, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, t, kvh, hd).astype(v.dtype)
    f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (dq.astype(qg.dtype), dk, dv, f0(q_pos), f0(k_pos))


_attend_flash.defvjp(_attend_flash_fwd, _attend_flash_bwd)


def attention(
    params,
    x: jax.Array,
    positions: jax.Array,
    *,
    n_kv: int,
    window: Optional[int] = None,
    kv_cache: Optional[tuple] = None,
    q_offset=0,
    rope_theta: float = 10000.0,
    chunk: int = 512,
    flash: bool = True,
    kv_chunk: int = 1024,
):
    """x: [B, S, d].  Returns (out [B, S, d], new_kv (k, v)).

    Train / prefill: ``kv_cache=None``; queries are chunked (flash-style —
    the [S, S] score matrix never materializes beyond [chunk, S]).

    Decode: ``kv_cache=(k, v)`` with shape [B, T, n_kv, hd]; S must be 1;
    ``q_offset`` is the absolute position of the new token.  If T is smaller
    than the context (sliding-window layers), the cache is a RING buffer:
    the token is written at slot ``q_offset % T`` and slot s holds absolute
    position ``q_offset - ((q_offset - s) mod T)``.
    """
    b, s, _ = x.shape
    h, hd = params["wq"].shape[1:]
    g = h // n_kv

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    q = shard(q, "batch", None, MODEL_AXIS, None)
    if kv_cache is None and s > 1:
        # §Perf (hypothesis H3): under sequence parallelism k/v would stay
        # seq-sharded over "model", turning every attention chunk into a
        # partial-softmax all-reduce.  Gathering k/v ONCE per layer (n_kv is
        # small) replaces ~2*n_chunks fp32 all-reduces with one bf16
        # all-gather.
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)

    if kv_cache is not None:
        assert s == 1, "decode path expects one token at a time"
        ck, cv = kv_cache
        t = ck.shape[1]
        slot = jnp.mod(jnp.asarray(q_offset, jnp.int32), t)
        k = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        # absolute position held by every ring slot (= identity when t covers
        # the whole context)
        sl = jnp.arange(t, dtype=jnp.int32)
        k_pos = q_offset - jnp.mod(q_offset - sl, t)
        q_pos = jnp.asarray(q_offset, jnp.int32)[None]
        ctx = _attend_block(
            q.reshape(b, s, n_kv, g, hd), k, v, q_pos, k_pos, window
        )
    else:
        k_pos = positions.astype(jnp.int32)
        q_all = q.reshape(b, s, n_kv, g, hd)
        use_flash = flash and s % kv_chunk == 0 and s >= kv_chunk
        if s > chunk and s % chunk == 0:
            n_chunks = s // chunk
            qc = q_all.reshape(b, n_chunks, chunk, n_kv, g, hd)
            pc = positions.astype(jnp.int32).reshape(n_chunks, chunk)

            def body(_, args):
                qi, pi = args
                if use_flash:
                    return None, _attend_flash(qi, k, v, pi, k_pos, window, kv_chunk)
                return None, _attend_block(qi, k, v, pi, k_pos, window)

            _, ctx = jax.lax.scan(
                body, None, (jnp.moveaxis(qc, 1, 0), pc)
            )  # [n_chunks, B, chunk, KV, G, hd]
            ctx = jnp.moveaxis(ctx, 0, 1).reshape(b, s, n_kv, g, hd)
        elif use_flash:
            ctx = _attend_flash(
                q_all, k, v, positions.astype(jnp.int32), k_pos, window, kv_chunk
            )
        else:
            ctx = _attend_block(q_all, k, v, positions.astype(jnp.int32), k_pos, window)

    ctx = ctx.reshape(b, s, h, hd)
    out = jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"])
    return shard(out, "batch", None, None), (k, v)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs():
    # Megatron TP on the ffn dim + FSDP storage sharding on d_model.
    return {
        "w_gate": P("data", MODEL_AXIS),
        "w_in": P("data", MODEL_AXIS),
        "w_out": P(MODEL_AXIS, "data"),
    }


def mlp_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    params = {
        "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype),
        "w_in": _dense_init(ks[1], (d_model, d_ff), dtype),
        "w_out": _dense_init(ks[2], (d_ff, d_model), dtype),
    }
    return params, mlp_specs()


def mlp(params, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shard(h, "batch", None, MODEL_AXIS)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])


# ---------------------------------------------------------------------------
# Generic MLP stack (GNN / recsys substrate)
# ---------------------------------------------------------------------------


def dense_stack_init(key, dims, dtype=jnp.float32, final_bias=True):
    """dims = [in, h1, ..., out]; returns list of {"w", "b"} params."""
    layers = []
    specs = []
    ks = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        layers.append(
            {
                "w": _dense_init(ks[i], (dims[i], dims[i + 1]), dtype),
                "b": jnp.zeros((dims[i + 1],), dtype),
            }
        )
        specs.append({"w": P(None, None), "b": P(None)})
    return layers, specs


def dense_stack(layers, x: jax.Array, act=jax.nn.relu, final_act=False) -> jax.Array:
    n = len(layers)
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x
