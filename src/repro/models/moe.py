"""Mixture-of-Experts layer (GShard top-k routing with capacity) — TPU-native
expert parallelism.

Dispatch strategy (DESIGN.md §5): tokens are sharded over the batch axes and
*replicated* over the ``model`` axis; experts are sharded over ``model``.
Inside a shard_map over the full mesh, every model shard
  1. routes its (replicated) local tokens,
  2. *selects* the tokens destined to its OWN E/P experts (sort-based ragged
     dispatch — argsort by expert id + rank-in-segment, capacity-dropped),
  3. runs its local expert FFNs,
  4. scatter-adds weighted outputs back to token positions, and
  5. psum's the partial outputs over ``model``.

No all-to-all of token activations is needed because tokens are already
replicated across the expert axis; the only collective is one [T_local, d]
all-reduce per MoE layer (same order as a Megatron TP MLP), which the
roofline analysis accounts under the collective term.

The identical dispatch body runs unsharded (expert_lo=0, all experts, no
psum) for single-device smoke tests and as the oracle for the sharded path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.layers import MODEL_AXIS, _dense_init

NEG_INF = float("-inf")


def moe_specs():
    # experts over "model" (expert parallelism), d_model FSDP over "data";
    # the dispatch shard_map's in_specs gather the "data" dim just-in-time.
    return {
        "router": P("data", None),
        "w_gate": P(MODEL_AXIS, "data", None),
        "w_in": P(MODEL_AXIS, "data", None),
        "w_out": P(MODEL_AXIS, None, "data"),
    }


def moe_init(key, d_model, d_ff, n_experts, dtype=jnp.bfloat16, expert_split: int = 1):
    """``expert_split`` > 1 stores each expert as ``split`` column-shards of
    its FFN ([E*split, d, f/split]) so that E*split divides the model-axis
    size even when E < mesh["model"] (grok-1: 8 experts x split 2 = 16).
    Splitting is EXACT for SwiGLU: the ffn dim is elementwise between the
    gate/in matmuls and the out matmul, so summing the halves' outputs
    reproduces the full expert."""
    ks = jax.random.split(key, 4)
    e_eff = n_experts * expert_split
    f_eff = d_ff // expert_split
    params = {
        "router": _dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": _dense_init(ks[1], (e_eff, d_model, f_eff), dtype),
        "w_in": _dense_init(ks[2], (e_eff, d_model, f_eff), dtype),
        "w_out": _dense_init(ks[3], (e_eff, f_eff, d_model), dtype),
    }
    return params, moe_specs()


def _dispatch_local(
    x2d: jax.Array,        # [T, d] local tokens
    router: jax.Array,     # [d, E]
    w_gate: jax.Array,     # [El, d, f'] — this shard's (split-)experts
    w_in: jax.Array,
    w_out: jax.Array,
    expert_lo: jax.Array,  # [] int32 — first (split-)expert id on this shard
    *,
    top_k: int,
    capacity: int,
    split: int = 1,
):
    """Route + select + compute + combine for one shard's expert slice.
    Returns (partial_out [T, d], aux_loss_partial)."""
    t, d = x2d.shape
    e = router.shape[1]
    el = w_gate.shape[0]

    logits = (x2d.astype(jnp.float32) @ router).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)               # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # Switch-style load-balance aux loss (computed on full router probs,
    # before any expert splitting).
    me = probs.mean(axis=0)                                          # [E]
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((t * top_k,), jnp.float32)
    ) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    if split > 1:
        # route to every column-shard of the chosen expert (exact for
        # SwiGLU; see moe_init)
        gate_idx = (
            gate_idx[..., None] * split + jnp.arange(split, dtype=gate_idx.dtype)
        ).reshape(t, top_k * split)
        gate_vals = jnp.repeat(gate_vals, split, axis=-1)
        top_k = top_k * split

    # ---- sort-based ragged dispatch over the flat (token, choice) list ----
    flat_expert = gate_idx.reshape(-1)                               # [T*k]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]

    # rank within expert segment
    idx = jnp.arange(t * top_k, dtype=jnp.int32)
    seg_first = jnp.concatenate(
        [jnp.ones((1,), bool), s_expert[1:] != s_expert[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(seg_first, idx, 0))
    rank = idx - seg_start

    local_e = s_expert - expert_lo
    keep = (rank < capacity) & (local_e >= 0) & (local_e < el)
    slot = jnp.where(keep, local_e * capacity + rank, el * capacity)  # spill row

    # §Perf (MoE dispatch v2): scatter token INDICES + gates into the
    # capacity buffer, then gather/scatter-add [El, capacity, d] tensors.
    # The naive formulation materializes [T*top_k, d] (8.6 GB fp32 per
    # qwen3 layer); this one touches only capacity-sized buffers.
    buf_tok = jnp.full((el * capacity + 1,), t, jnp.int32)
    buf_tok = buf_tok.at[slot].set(jnp.where(keep, s_token, t))
    buf_gate = jnp.zeros((el * capacity + 1,), jnp.float32)
    buf_gate = buf_gate.at[slot].set(jnp.where(keep, s_gate, 0.0))
    buf_tok = buf_tok[: el * capacity]
    buf_gate = buf_gate[: el * capacity]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    buf = x_pad[buf_tok].reshape(el, capacity, d)

    # expert FFN (SwiGLU), batched over local experts
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_in)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(el * capacity, d)

    # combine: gate-weight in place, one scatter-add back to token rows
    y = y * buf_gate[:, None].astype(y.dtype)
    out = jnp.zeros((t + 1, d), x2d.dtype).at[buf_tok].add(y)[:t]
    return out, aux


def moe_apply(
    params,
    x: jax.Array,  # [B, S, d]
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    expert_split: int = 1,
    mesh: Optional[jax.sharding.Mesh] = None,
):
    """Returns (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    e_eff = n_experts * expert_split
    if mesh is not None and MODEL_AXIS in mesh.axis_names and mesh.shape[MODEL_AXIS] > 1:
        p = mesh.shape[MODEL_AXIS]
        el = e_eff // p
        batch_axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        t_local = (b // dp) * s
        capacity = max(8, int(t_local * top_k / n_experts * capacity_factor))

        def body(router, w_gate, w_in, w_out, xb):
            lo = (jax.lax.axis_index(MODEL_AXIS) * el).astype(jnp.int32)
            x2d = xb.reshape(-1, d)
            out, aux = _dispatch_local(
                x2d, router, w_gate, w_in, w_out, lo,
                top_k=top_k, capacity=capacity, split=expert_split,
            )
            out = jax.lax.psum(out, MODEL_AXIS)
            aux = jax.lax.pmean(aux, MODEL_AXIS)
            aux = jax.lax.pmean(aux, batch_axes)
            return out.reshape(xb.shape), aux

        out, aux = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(None, None),                # router replicated over manual
                P(MODEL_AXIS, None, None),    # experts sharded (the "data"
                P(MODEL_AXIS, None, None),    #   storage dim is gathered
                P(MODEL_AXIS, None, None),    #   just-in-time = FSDP)
                P(batch_axes, None, None),    # tokens batch-sharded
            ),
            out_specs=(P(batch_axes, None, None), P()),
            check_vma=False,
        )(params["router"], params["w_gate"], params["w_in"], params["w_out"], x)
        return out, aux

    # unsharded oracle path
    capacity = max(8, int(b * s * top_k / n_experts * capacity_factor))
    out, aux = _dispatch_local(
        x.reshape(-1, d),
        params["router"],
        params["w_gate"],
        params["w_in"],
        params["w_out"],
        jnp.int32(0),
        top_k=top_k,
        capacity=capacity,
        split=expert_split,
    )
    return out.reshape(b, s, d), aux
