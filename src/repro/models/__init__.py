"""Assigned-architecture substrate: LM transformers (dense/MoE/sliding),
MeshGraphNet GNN, and recsys towers."""
