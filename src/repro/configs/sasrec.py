"""sasrec [arXiv:1808.09781; paper] — self-attentive sequential recsys.
embed_dim=50, 2 blocks, 1 head, seq_len=50; 1M-item corpus for retrieval."""
from repro.configs.common import RecsysArch
from repro.models.recsys.sasrec import SASRecConfig

ARCH = RecsysArch(
    arch_id="sasrec",
    cfg=SASRecConfig(embed_dim=50, n_blocks=2, n_heads=1, seq_len=50,
                     n_items=1_000_000),
)
