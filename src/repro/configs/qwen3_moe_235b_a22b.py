"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf] — MoE.
94L d_model=4096 64H (GQA kv=4, head_dim=128) per-expert d_ff=1536
vocab=151936, 128 experts top-8."""
from repro.configs.common import LMArch
from repro.models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="qwen3-moe-235b-a22b",
    cfg=TransformerConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv=4,
        head_dim=128,
        d_ff=1536,
        vocab=151936,
        moe_experts=128,
        moe_top_k=8,
    ),
)
