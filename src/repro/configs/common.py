"""Arch -> (step_fn, abstract inputs, shardings) cell builders.

Every assigned architecture exposes ``build_cell(shape_name, mesh) -> Cell``;
the dry-run jits/lowers/compiles the cell on the production mesh, the
roofline reads its cost analysis, and smoke tests run REDUCED configs of the
same families through the same step functions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.launch.mesh import batch_axes_of, data_parallelism
from repro.models import gnn as gnn_mod
from repro.models import layers as layers_mod
from repro.models import transformer as tf
from repro.models.recsys import dien as dien_mod
from repro.models.recsys import dlrm as dlrm_mod
from repro.models.recsys import mind as mind_mod
from repro.models.recsys import sasrec as sasrec_mod
from repro.train.optimizer import adamw_init, adamw_specs, adamw_update, cosine_schedule

SDS = jax.ShapeDtypeStruct


class Cell(NamedTuple):
    name: str                 # "<arch>/<shape>"
    step_fn: Callable
    args: tuple               # abstract inputs (ShapeDtypeStructs)
    in_specs: tuple           # PartitionSpec pytrees matching args
    out_specs: Any            # PartitionSpec pytree (or None to infer)
    meta: dict                # roofline metadata (model_flops etc.)
    donate: tuple = ()        # argnums donated (in-place update buffers)


def _is_spec(x):
    return isinstance(x, P)


def shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=_is_spec
    )


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclasses.dataclass
class LMArch:
    arch_id: str
    cfg: tf.TransformerConfig
    family: str = "lm"

    def shape_names(self):
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if any(w is not None for w in self.cfg.window_pattern):
            names.append("long_500k")  # sub-quadratic archs only
        return names

    def build_cell(self, shape_name: str, mesh) -> Cell:
        layers_mod.set_batch_axes_for_mesh(mesh)
        sh = LM_SHAPES[shape_name]
        cfg = self.cfg
        batch_ax = batch_axes_of(mesh)
        all_ax = tuple(mesh.axis_names)
        params_abs = tf.abstract_params(cfg)
        pspecs = tf.specs(cfg)
        b, s = sh["batch"], sh["seq"]
        meta = dict(
            family="lm",
            arch=self.arch_id,
            shape=shape_name,
            kind=sh["kind"],
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            tokens=b * s if sh["kind"] != "decode" else b,
        )

        if sh["kind"] == "train":
            # §Perf iteration 3: dense-LM training is pure-FSDP on the
            # single-pod mesh — the batch spans BOTH axes (1 seq/chip), so
            # the per-layer collectives are weight gathers (~2 x params/256)
            # instead of Megatron-TP activation gathers (~8 x B_loc*S*d).
            # MoE archs keep the hybrid (tokens must stay replicated over
            # "model" for the expert dispatch); multi-pod keeps TP+SP
            # (global batch 256 < 512 chips).
            fsdp = (not cfg.is_moe) and "pod" not in mesh.axis_names
            train_batch_ax = ("data", "model") if fsdp else batch_ax
            layers_mod.set_batch_axes(train_batch_ax)
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = adamw_specs(pspecs)
            batch_abs = {
                "tokens": SDS((b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32),
            }
            bspecs = {
                "tokens": P(train_batch_ax, None),
                "labels": P(train_batch_ax, None),
            }

            def train_step(params, opt, batch):
                loss, grads = jax.value_and_grad(tf.lm_loss)(
                    params, batch, cfg, mesh
                )
                lr = cosine_schedule(
                    opt.step, base_lr=3e-4, warmup=2000, total=100_000
                )
                new_p, new_o = adamw_update(grads, opt, params, lr=lr)
                return new_p, new_o, loss

            return Cell(
                name=f"{self.arch_id}/{shape_name}",
                step_fn=train_step,
                args=(params_abs, opt_abs, batch_abs),
                in_specs=(pspecs, ospecs, bspecs),
                out_specs=(pspecs, ospecs, P()),
                meta=meta,
                donate=(0, 1),
            )

        if sh["kind"] == "prefill":
            tokens_abs = SDS((b, s), jnp.int32)
            cspecs = tf.cache_specs(cfg, batch=batch_ax, seq=("model",))

            def prefill_step(params, tokens):
                return tf.serve_prefill(params, tokens, cfg, mesh, max_len=s)

            return Cell(
                name=f"{self.arch_id}/{shape_name}",
                step_fn=prefill_step,
                args=(params_abs, tokens_abs),
                in_specs=(pspecs, P(batch_ax, None)),
                out_specs=((P(batch_ax, "model")), cspecs),
                meta=meta,
            )

        # decode
        long_ctx = b == 1
        cache_batch = () if long_ctx else batch_ax
        cache_seq = all_ax if long_ctx else ("model",)
        caches_abs = jax.eval_shape(
            functools.partial(tf.init_cache, cfg, b, sh["seq"])
        )
        cspecs = tf.cache_specs(cfg, batch=cache_batch, seq=cache_seq)
        token_abs = SDS((b, 1), jnp.int32)
        off_abs = SDS((), jnp.int32)

        def decode_step(params, caches, token, q_offset):
            return tf.serve_step(params, caches, token, q_offset, cfg, mesh)

        tok_spec = P(None, None) if long_ctx else P(batch_ax, None)
        logit_spec = P(None, "model") if long_ctx else P(batch_ax, "model")
        return Cell(
            name=f"{self.arch_id}/{shape_name}",
            step_fn=decode_step,
            args=(params_abs, caches_abs, token_abs, off_abs),
            in_specs=(pspecs, cspecs, tok_spec, P()),
            out_specs=(logit_spec, cspecs),
            meta=meta,
            donate=(1,),
        )


# ---------------------------------------------------------------------------
# GNN (meshgraphnet)
# ---------------------------------------------------------------------------

GNN_SHAPES = {
    # (n_nodes, n_edges, d_feat, note)
    "full_graph_sm": dict(n_nodes=2_708, n_edges=10_556, d_feat=1_433),
    "minibatch_lg": dict(n_nodes=169_984, n_edges=168_960, d_feat=602),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    "molecule": dict(n_nodes=30 * 128, n_edges=64 * 128, d_feat=16),
}


@dataclasses.dataclass
class GNNArch:
    arch_id: str
    base: gnn_mod.GNNConfig
    family: str = "gnn"

    def shape_names(self):
        return list(GNN_SHAPES)

    def config_for(self, shape_name: str) -> gnn_mod.GNNConfig:
        sh = GNN_SHAPES[shape_name]
        return dataclasses.replace(self.base, d_feat=sh["d_feat"])

    def build_cell(self, shape_name: str, mesh) -> Cell:
        sh = GNN_SHAPES[shape_name]
        cfg = self.config_for(shape_name)
        ndev = mesh.devices.size
        n = _round_up(sh["n_nodes"], ndev)
        e = _round_up(sh["n_edges"], ndev)
        axes = tuple(mesh.axis_names)

        params_abs = jax.eval_shape(
            lambda k: gnn_mod._init_params(k, cfg), jax.random.PRNGKey(0)
        )
        pspecs = gnn_mod.specs(cfg)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        ospecs = adamw_specs(pspecs)

        graph_abs = {
            "node_feat": SDS((n, cfg.d_feat), jnp.float32),
            "edge_feat": SDS((e, cfg.d_edge), jnp.float32),
            "src": SDS((e,), jnp.int32),
            "dst": SDS((e,), jnp.int32),
            "targets": SDS((n, cfg.out_dim), jnp.float32),
        }
        gspecs = gnn_mod.data_specs(axes)

        def train_step(params, opt, graph):
            loss, grads = jax.value_and_grad(gnn_mod.mse_loss)(
                params, graph, cfg, mesh
            )
            lr = cosine_schedule(opt.step, base_lr=1e-3, warmup=100, total=10_000)
            new_p, new_o = adamw_update(grads, opt, params, lr=lr)
            return new_p, new_o, loss

        meta = dict(
            family="gnn",
            arch=self.arch_id,
            shape=shape_name,
            kind="train",
            n_nodes=n,
            n_edges=e,
            d_hidden=cfg.d_hidden,
            n_layers=cfg.n_layers,
        )
        return Cell(
            name=f"{self.arch_id}/{shape_name}",
            step_fn=train_step,
            args=(params_abs, opt_abs, graph_abs),
            in_specs=(pspecs, ospecs, gspecs),
            out_specs=(pspecs, ospecs, P()),
            meta=meta,
            donate=(0, 1),
        )


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512, n_cand=1_000),
    "serve_bulk": dict(kind="serve", batch=262_144, n_cand=1_000),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}

_RECSYS_MODS = {
    "dlrm-rm2": dlrm_mod,
    "sasrec": sasrec_mod,
    "mind": mind_mod,
    "dien": dien_mod,
}


@dataclasses.dataclass
class RecsysArch:
    arch_id: str
    cfg: Any
    family: str = "recsys"

    @property
    def mod(self):
        return _RECSYS_MODS[self.arch_id]

    def shape_names(self):
        return list(RECSYS_SHAPES)

    # ---- batch builders per model kind ------------------------------------

    def _train_batch(self, b):
        cfg = self.cfg
        if self.arch_id == "dlrm-rm2":
            abs_ = {
                "dense": SDS((b, cfg.n_dense), jnp.float32),
                "sparse": SDS((b, cfg.n_sparse), jnp.int32),
                "labels": SDS((b,), jnp.float32),
            }
        elif self.arch_id == "sasrec":
            s = cfg.seq_len
            abs_ = {
                "hist": SDS((b, s), jnp.int32),
                "pos": SDS((b, s), jnp.int32),
                "neg": SDS((b, s, 4), jnp.int32),
            }
        elif self.arch_id == "mind":
            s = cfg.seq_len
            abs_ = {
                "hist": SDS((b, s), jnp.int32),
                "pos": SDS((b,), jnp.int32),
                "neg": SDS((b, 20), jnp.int32),
            }
        else:  # dien
            s = cfg.seq_len
            abs_ = {
                "hist": SDS((b, s), jnp.int32),
                "target": SDS((b,), jnp.int32),
                "labels": SDS((b,), jnp.float32),
                "aux_neg": SDS((b, s), jnp.int32),
            }
        return abs_

    def loss_fn(self):
        return {
            "dlrm-rm2": dlrm_mod.bce_loss,
            "sasrec": sasrec_mod.sampled_softmax_loss,
            "mind": mind_mod.sampled_softmax_loss,
            "dien": dien_mod.bce_loss,
        }[self.arch_id]

    def build_cell(self, shape_name: str, mesh) -> Cell:
        layers_mod.set_batch_axes_for_mesh(mesh)
        sh = RECSYS_SHAPES[shape_name]
        cfg = self.cfg
        batch_ax = batch_axes_of(mesh)
        mod = self.mod
        params_abs = jax.eval_shape(
            lambda k: mod._init_params(k, cfg), jax.random.PRNGKey(0)
        )
        pspecs = mod.specs(cfg)
        b = sh["batch"]
        meta = dict(
            family="recsys", arch=self.arch_id, shape=shape_name, kind=sh["kind"],
            batch=b, n_cand=sh.get("n_cand", 0),
        )

        if sh["kind"] == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = adamw_specs(pspecs)
            batch_abs = self._train_batch(b)
            bspecs = jax.tree.map(
                lambda a: P(batch_ax, *([None] * (len(a.shape) - 1))),
                batch_abs,
            )
            loss_fn = self.loss_fn()

            def train_step(params, opt, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
                lr = cosine_schedule(opt.step, base_lr=1e-3, warmup=500, total=50_000)
                new_p, new_o = adamw_update(grads, opt, params, lr=lr)
                return new_p, new_o, loss

            return Cell(
                name=f"{self.arch_id}/{shape_name}",
                step_fn=train_step,
                args=(params_abs, opt_abs, batch_abs),
                in_specs=(pspecs, ospecs, bspecs),
                out_specs=(pspecs, ospecs, P()),
                meta=meta,
                donate=(0, 1),
            )

        if sh["kind"] == "serve":
            nc = sh["n_cand"]
            # §Perf: serving REPLICATES the item-embedding table when it is
            # small (sasrec/mind/dien: 72-256 MB) — candidate-gather lookups
            # become local instead of cross-shard collectives.  Training
            # keeps tables row-sharded (optimizer state).  DLRM's 26 x 1M
            # tables (6.7 GB) stay sharded.
            if self.arch_id != "dlrm-rm2" and "item_emb" in pspecs:
                pspecs = dict(pspecs)
                pspecs["item_emb"] = P(None, None)
            if self.arch_id == "dlrm-rm2":
                batch_abs = {
                    "dense": SDS((b, cfg.n_dense), jnp.float32),
                    "sparse": SDS((b, cfg.n_sparse), jnp.int32),
                }
                bspecs = {
                    "dense": P(batch_ax, None),
                    "sparse": P(batch_ax, None),
                }

                def serve_step(params, batch):
                    return dlrm_mod.forward(params, batch, cfg)

                out_spec = P(batch_ax)
                args = (params_abs, batch_abs)
                in_specs = (pspecs, bspecs)
            elif self.arch_id == "dien":
                batch_abs = {
                    "hist": SDS((b, cfg.seq_len), jnp.int32),
                    "target": SDS((b,), jnp.int32),
                }
                bspecs = {"hist": P(batch_ax, None), "target": P(batch_ax)}

                def serve_step(params, batch):
                    logit, _ = dien_mod.forward(params, batch, cfg)
                    return logit

                out_spec = P(batch_ax)
                args = (params_abs, batch_abs)
                in_specs = (pspecs, bspecs)
            else:  # sasrec / mind: re-rank nc candidates per user
                s = cfg.seq_len
                batch_abs = {
                    "hist": SDS((b, s), jnp.int32),
                    "cand": SDS((b, nc), jnp.int32),
                }
                bspecs = {"hist": P(batch_ax, None), "cand": P(batch_ax, None)}
                rerank = _make_rerank(mod, self.arch_id, cfg)
                serve_step = rerank
                out_spec = P(batch_ax, None)
                args = (params_abs, batch_abs)
                in_specs = (pspecs, bspecs)

            return Cell(
                name=f"{self.arch_id}/{shape_name}",
                step_fn=serve_step,
                args=args,
                in_specs=in_specs,
                out_specs=out_spec,
                meta=meta,
            )

        # retrieval_cand: 1 user vs 1M candidates
        nc = sh["n_cand"]
        if self.arch_id == "dlrm-rm2":
            # bulk candidate scoring through the ranker: 1M candidate rows
            batch_abs = {
                "dense": SDS((nc, cfg.n_dense), jnp.float32),
                "sparse": SDS((nc, cfg.n_sparse), jnp.int32),
            }
            bspecs = {"dense": P(batch_ax, None), "sparse": P(batch_ax, None)}

            def retrieval_step(params, batch):
                scores = dlrm_mod.forward(params, batch, cfg)
                vals, ids = jax.lax.top_k(scores, 100)
                return {"scores": vals, "ids": ids}

            out_spec = {"scores": P(None), "ids": P(None)}
            args = (params_abs, batch_abs)
            in_specs = (pspecs, bspecs)
        else:
            s = cfg.seq_len
            hist_abs = SDS((b, s), jnp.int32)
            msize = mesh.shape.get("model", 1)
            shard_topk = msize > 1 and cfg.n_items % msize == 0

            def _user_vectors(params, hist):
                """[B, K, d] user-side query vectors (K=1 except MIND)."""
                if self.arch_id == "mind":
                    return mind_mod.interest_capsules(params, hist, cfg)
                if self.arch_id == "sasrec":
                    return sasrec_mod.user_embedding(params, hist, cfg)[:, None]
                # dien
                mask = hist >= 0
                e = jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0)
                states = dien_mod._run_gru(params["gru1"], e, mask, cfg.gru_dim)
                lengths = jnp.maximum(jnp.sum(mask, axis=1) - 1, 0)
                h_last = jnp.take_along_axis(
                    states, lengths[:, None, None], axis=1
                )[:, 0]
                return (h_last @ params["attn_w"].T)[:, None]

            if shard_topk:
                # §Perf: shard-LOCAL top-k + tiny merge — the baseline
                # gathers the full [B, n_items] score row (4 MB) to run a
                # global top-k; this gathers 2*P*k*B values (~13 KB).
                def retrieval_step(params, hist):
                    u = _user_vectors(params, hist)

                    def body(emb_blk, u):
                        sc = jnp.einsum(
                            "bkd,nd->bkn", u, emb_blk,
                            preferred_element_type=jnp.float32,
                        )
                        sc = jnp.max(sc, axis=1)              # over interests
                        vals, idx = jax.lax.top_k(sc, 100)
                        off = jax.lax.axis_index("model") * emb_blk.shape[0]
                        idx = idx + off
                        allv = jax.lax.all_gather(vals, "model")  # [P, B, k]
                        alli = jax.lax.all_gather(idx, "model")
                        p_, b_, k_ = allv.shape
                        allv = jnp.moveaxis(allv, 0, 1).reshape(b_, p_ * k_)
                        alli = jnp.moveaxis(alli, 0, 1).reshape(b_, p_ * k_)
                        mv, sel = jax.lax.top_k(allv, 100)
                        return mv, jnp.take_along_axis(alli, sel, axis=-1)

                    vals, ids = shard_map(
                        body,
                        mesh=mesh,
                        in_specs=(P("model", None), P(None, None, None)),
                        out_specs=(P(None, None), P(None, None)),
                        check_vma=False,
                    )(params["item_emb"], u)
                    return {"scores": vals, "ids": ids}
            else:
                def retrieval_step(params, hist):
                    scores = mod.retrieval_scores(params, hist, cfg)
                    vals, ids = jax.lax.top_k(scores, 100)
                    return {"scores": vals, "ids": ids}

            out_spec = {"scores": P(None, None), "ids": P(None, None)}
            args = (params_abs, hist_abs)
            in_specs = (pspecs, P(None, None))

        return Cell(
            name=f"{self.arch_id}/{shape_name}",
            step_fn=retrieval_step,
            args=args,
            in_specs=in_specs,
            out_specs=out_spec,
            meta=meta,
        )


def _make_rerank(mod, arch_id, cfg):
    def rerank(params, batch):
        cand_e = jnp.take(
            params["item_emb"], jnp.maximum(batch["cand"], 0), axis=0
        )  # [B, nc, d]
        if arch_id == "mind":
            interests = mind_mod.interest_capsules(params, batch["hist"], cfg)
            sc = jnp.einsum("bkd,bnd->bkn", interests, cand_e)
            return jnp.max(sc, axis=1)
        u = sasrec_mod.user_embedding(params, batch["hist"], cfg)
        return jnp.einsum("bd,bnd->bn", u, cand_e)

    return rerank
