"""meshgraphnet [arXiv:2010.03409; unverified] — encode-process-decode GNN.
15 processor layers, d_hidden=128, sum aggregator, 2-layer MLPs."""
from repro.configs.common import GNNArch
from repro.models.gnn import GNNConfig

ARCH = GNNArch(
    arch_id="meshgraphnet",
    base=GNNConfig(
        name="meshgraphnet",
        n_layers=15,
        d_hidden=128,
        mlp_layers=2,
        aggregator="sum",
    ),
)
