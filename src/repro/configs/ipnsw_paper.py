"""The paper's own serving configuration: ip-NSW / ip-NSW+ index parameters
used by benchmarks and the serving examples (paper §5: angular graph fixed at
M=10, l=10; inner-product graph M/ef as tuned per dataset)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperIndexConfig:
    max_degree: int = 16          # M for the inner-product graph
    ef_construction: int = 64     # l during construction
    ang_degree: int = 10          # paper: fixed, no tuning
    ang_ef: int = 10
    k_angular: int = 10
    k: int = 10                   # top-10 MIPS throughout the paper
    insert_batch: int = 256


PAPER_INDEX = PaperIndexConfig()
