"""gemma3-12b [hf:google/gemma-3-1b-pt family; unverified] — dense GQA with
5:1 local:global sliding-window attention (window 1024), 128k-class context.
48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144.
Tied embeddings (gemma family).  The 5:1 hybrid makes decode memory
sub-quadratic -> long_500k runs for this arch."""
from repro.configs.common import LMArch
from repro.models.transformer import TransformerConfig

WINDOW = 1024

ARCH = LMArch(
    arch_id="gemma3-12b",
    cfg=TransformerConfig(
        name="gemma3-12b",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv=8,
        head_dim=256,
        d_ff=15360,
        vocab=262144,
        window_pattern=(WINDOW, WINDOW, WINDOW, WINDOW, WINDOW, None),
        rope_theta=1_000_000.0,
        tied_embed=True,
    ),
)
