"""dien [arXiv:1809.03672; unverified] — interest evolution CTR.
embed 18, seq 100, gru_dim 108, AUGRU, MLP 200-80; 1M-item corpus."""
from repro.configs.common import RecsysArch
from repro.models.recsys.dien import DIENConfig

ARCH = RecsysArch(
    arch_id="dien",
    cfg=DIENConfig(embed_dim=18, seq_len=100, gru_dim=108, mlp=(200, 80),
                   n_items=1_000_000),
)
