"""grok-1-314b [hf:xai-org/grok-1; unverified] — MoE.
64L d_model=6144 48H (GQA kv=8, head_dim=128) expert d_ff=32768
vocab=131072, 8 experts top-2."""
from repro.configs.common import LMArch
from repro.models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="grok-1-314b",
    cfg=TransformerConfig(
        name="grok-1-314b",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        head_dim=128,
        d_ff=32768,
        vocab=131072,
        moe_experts=8,
        moe_top_k=2,
        moe_expert_split=2,  # 8 experts x 2 ffn column-shards = 16-way model axis
    ),
)
