"""mind [arXiv:1904.08030; unverified] — multi-interest retrieval.
embed 64, 4 interests, 3 capsule-routing iterations; 1M-item corpus."""
from repro.configs.common import RecsysArch
from repro.models.recsys.mind import MINDConfig

ARCH = RecsysArch(
    arch_id="mind",
    cfg=MINDConfig(embed_dim=64, n_interests=4, capsule_iters=3, seq_len=50,
                   n_items=1_000_000),
)
