"""dlrm-rm2 [arXiv:1906.00091; paper] — DLRM RM2-scale.
13 dense, 26 sparse (1M-row tables), embed 64, bot 13-512-256-64,
top 512-512-256-1, dot interaction."""
from repro.configs.common import RecsysArch
from repro.models.recsys.dlrm import DLRMConfig

ARCH = RecsysArch(
    arch_id="dlrm-rm2",
    cfg=DLRMConfig(n_dense=13, n_sparse=26, embed_dim=64, n_rows=1_000_000,
                   bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1)),
)
