"""Assigned-architecture registry: --arch <id> resolves here."""
import importlib

_MODULES = {
    "internlm2-20b": "repro.configs.internlm2_20b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "sasrec": "repro.configs.sasrec",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "mind": "repro.configs.mind",
    "dien": "repro.configs.dien",
}

ARCH_IDS = list(_MODULES)


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).ARCH


def all_cells():
    """Every (arch, shape) pair — the 40 assigned cells."""
    cells = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in arch.shape_names():
            cells.append((aid, shape))
    return cells
