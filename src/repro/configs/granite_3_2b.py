"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base; hf] — dense GQA.
40L d_model=2048 32H (GQA kv=8, head_dim=64) d_ff=8192 vocab=49155.

vocab is PADDED 49155 -> 49408 (multiple of 256) for 16-way vocab sharding +
MXU alignment — the standard Megatron `make-vocab-size-divisible-by` trick;
the 253 pad ids are never emitted by the tokenizer and their logits are
dead rows."""
from repro.configs.common import LMArch
from repro.models.transformer import TransformerConfig

VOCAB_RAW = 49155
VOCAB_PADDED = 49408

ARCH = LMArch(
    arch_id="granite-3-2b",
    cfg=TransformerConfig(
        name="granite-3-2b",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv=8,
        head_dim=64,
        d_ff=8192,
        vocab=VOCAB_PADDED,
    ),
)
