"""internlm2-20b [arXiv:2403.17297; hf] — dense GQA transformer.
48L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=16384 vocab=92544."""
from repro.configs.common import LMArch
from repro.models.transformer import TransformerConfig

ARCH = LMArch(
    arch_id="internlm2-20b",
    cfg=TransformerConfig(
        name="internlm2-20b",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        head_dim=128,
        d_ff=16384,
        vocab=92544,
    ),
)
