"""Host-side metrics registry — counters, gauges, histograms, vectors,
timing spans and an event timeline, exportable as Prometheus text or JSONL.

This is the aggregation point the serving loop, the mutation layer and the
build drivers report into, replacing the scattered ad-hoc counters that grew
per subsystem.  Design constraints, in order:

  wall-clock free by default — every *value* recorded from the serving loop
      is computed from the loop's injected clock (launch/serve_loop.py never
      reads wall time; tests pin that), so a VirtualClock run produces a
      bit-identical registry.  Only ``span()`` reads ``time.perf_counter``,
      and it is used exclusively by host-side drivers (build phases) that
      already live on the wall clock.
  cheap enough to leave on — recording is a dict lookup + a float add; the
      ``bench=obs_overhead`` row (benchmarks/serve_bench.py) measures the
      always-on cost against an uninstrumented run and
      scripts/check_bench_json.py FAILS CI when it exceeds 5%.
  dependency-free — pure Python/numpy; nothing in ``repro.obs`` imports
      ``repro.core``, so every layer (core, kernels, launch) may import the
      registry without cycles.

Metric types:
  Counter       — monotonically increasing float (``_total`` names).
  Gauge         — last-write-wins float (health ratios, debts).
  Histogram     — fixed upper-bound buckets (Prometheus ``le`` convention,
                  +Inf implied) with count/sum, so quantile-ish questions
                  and mean are answerable from the export alone.
  VectorCounter — a fixed-length vector of counts with a label name per
                  index (the per-norm-band eval histogram: band -> evals).

The *timeline* is the part a scalar snapshot cannot carry: ``event(name, t,
**fields)`` appends a timestamped record (dispatches, responses, churn
events, walk-trace aggregates) and the JSONL export writes one object per
line — ``scripts/obs_report.py`` renders a run's JSONL into the norm-decile
heat table and latency timeline (the paper's Fig-4/5 recomputed from served
traffic).
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Default latency-style buckets (seconds): ~exponential, 100us .. 10s.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v

    def collect(self) -> dict:
        return {"kind": self.kind, "name": self.name, "help": self.help,
                "value": self.value}


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def collect(self) -> dict:
        return {"kind": self.kind, "name": self.name, "help": self.help,
                "value": self.value}


class Histogram:
    """Prometheus-style cumulative-bucket histogram (uppers + implicit
    +Inf), tracking count and sum alongside."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        if not buckets or any(b >= a for a, b in zip(buckets[1:], buckets)):
            raise ValueError(f"histogram {name} buckets must be strictly "
                             f"ascending and non-empty: {buckets}")
        self.name, self.help = name, help
        self.uppers = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.uppers) + 1)  # last = +Inf overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        for i, ub in enumerate(self.uppers):
            if v <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def collect(self) -> dict:
        return {"kind": self.kind, "name": self.name, "help": self.help,
                "count": self.count, "sum": self.sum,
                "buckets": list(self.uppers), "counts": list(self.counts)}


class VectorCounter:
    """Fixed-length vector of counts with one label value per index —
    e.g. ``walk_evals_by_band`` maps norm-band -> total evaluations."""

    kind = "vector"

    def __init__(self, name: str, length: int, help: str = "",
                 label: str = "index"):
        if length <= 0:
            raise ValueError(f"vector {name} needs a positive length")
        self.name, self.help, self.label = name, help, label
        self.values = np.zeros(length, np.float64)

    def add(self, values) -> None:
        v = np.asarray(values, np.float64)
        if v.shape != self.values.shape:
            raise ValueError(
                f"vector {self.name} expects shape {self.values.shape}, "
                f"got {v.shape}"
            )
        self.values += v

    def inc(self, index: int, v: float = 1.0) -> None:
        self.values[index] += v

    def collect(self) -> dict:
        return {"kind": self.kind, "name": self.name, "help": self.help,
                "label": self.label, "values": self.values.tolist()}


class MetricsRegistry:
    """Name -> metric store + event timeline.  Metric constructors are
    get-or-create (idempotent per name); asking for an existing name with a
    different type is a hard error — silent type drift would corrupt the
    export."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self.events: List[dict] = []

    # -- constructors ------------------------------------------------------

    def _get_or_create(self, cls, name: str, *args, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"requested {cls.__name__}"
            )
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets)

    def vector(self, name: str, length: int, help: str = "",
               label: str = "index") -> VectorCounter:
        return self._get_or_create(VectorCounter, name, length, help, label)

    # -- spans (host wall time — build drivers only, never the serve loop) -

    @contextmanager
    def span(self, name: str, help: str = ""):
        """Time a host-side phase into ``{name}_seconds``.  Measures the
        driver's wall time; jax dispatch is async, so device work may
        overlap the span unless the caller blocks — documented per site."""
        h = self.histogram(f"{name}_seconds", help)
        t0 = time.perf_counter()
        try:
            yield h
        finally:
            h.observe(time.perf_counter() - t0)

    # -- timeline ----------------------------------------------------------

    def event(self, name: str, t: float, **fields) -> None:
        """Append one timestamped timeline record.  ``t`` is whatever clock
        the caller lives on (the serve loop passes its injected clock's
        times, so virtual runs replay bit-identically)."""
        self.events.append({"event": name, "t": float(t), **fields})

    # -- export ------------------------------------------------------------

    def collect(self) -> List[dict]:
        return [m.collect() for _, m in sorted(self._metrics.items())]

    def get(self, name: str):
        return self._metrics.get(name)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (text/plain; version 0.0.4)."""
        out: List[str] = []
        for m in self.collect():
            name, kind = m["name"], m["kind"]
            if m["help"]:
                out.append(f"# HELP {name} {m['help']}")
            if kind in ("counter", "gauge"):
                out.append(f"# TYPE {name} {kind}")
                out.append(f"{name} {_fmt(m['value'])}")
            elif kind == "histogram":
                out.append(f"# TYPE {name} histogram")
                cum = 0
                for ub, c in zip(m["buckets"], m["counts"]):
                    cum += c
                    out.append(f'{name}_bucket{{le="{_fmt(ub)}"}} {cum}')
                cum += m["counts"][-1]
                out.append(f'{name}_bucket{{le="+Inf"}} {cum}')
                out.append(f"{name}_sum {_fmt(m['sum'])}")
                out.append(f"{name}_count {m['count']}")
            elif kind == "vector":
                out.append(f"# TYPE {name} counter")
                for i, v in enumerate(m["values"]):
                    out.append(f'{name}{{{m["label"]}="{i}"}} {_fmt(v)}')
        return "\n".join(out) + "\n"

    def export_jsonl(self, path: str, meta: Optional[dict] = None) -> None:
        """One JSON object per line: a ``meta`` header, every metric
        snapshot, then the event timeline in record order — the format
        ``scripts/obs_report.py`` renders."""
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "meta", **(meta or {})}) + "\n")
            for m in self.collect():
                # the record kind is "metric"; the metric's own kind
                # (counter/gauge/...) rides in "type" to avoid a key clash
                rec = {"kind": "metric", "type": m["kind"]}
                rec.update((k, v) for k, v in m.items() if k != "kind")
                f.write(json.dumps(rec) + "\n")
            for e in self.events:
                f.write(json.dumps({"kind": "event", **e}) + "\n")


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers without the trailing .0."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# ---------------------------------------------------------------------------
# Process-global default registry (build-phase spans and other sites without
# an injected registry report here; serve.py snapshots it into --metrics-out)
# ---------------------------------------------------------------------------

_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _GLOBAL


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous one."""
    global _GLOBAL
    prev, _GLOBAL = _GLOBAL, registry
    return prev
