"""Snapshot export + report rendering for the metrics registry.

Two wire formats, dispatched on file extension by :func:`write_metrics`:

  ``*.prom``  — Prometheus text exposition (scalar snapshot; scrape-shaped).
  anything else — JSONL: one ``meta`` header line, one line per metric, one
      line per timeline event (``MetricsRegistry.export_jsonl``).  JSONL is
      the lossless format: it keeps the event timeline, which is what the
      report renderers below need.

The renderers are plain-string functions (no terminal deps) so
``scripts/obs_report.py`` stays a thin argparse wrapper and tests can pin
the rendering directly:

  render_band_table    — the norm-band eval histogram as a heat table: the
      paper's Fig-5 recomputed from served traffic.
  render_latency_timeline — per-time-bin p50/p99 from ``response`` events:
      "why did p99 spike at t=3s" becomes answerable from the export alone.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

import numpy as np


def write_metrics(registry, path: str, meta: Optional[dict] = None) -> str:
    """Write a registry snapshot; format chosen by extension.  Returns the
    format written ("prometheus" | "jsonl")."""
    if path.endswith(".prom"):
        with open(path, "w") as f:
            f.write(registry.to_prometheus())
        return "prometheus"
    registry.export_jsonl(path, meta=meta)
    return "jsonl"


def load_jsonl(path: str) -> dict:
    """Parse a JSONL export back into ``{meta, metrics: {name: rec},
    events: [rec]}`` — the inverse of ``export_jsonl``."""
    meta: dict = {}
    metrics: Dict[str, dict] = {}
    events: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.pop("kind", None)
            if kind == "meta":
                meta = rec
            elif kind == "metric":
                rec["kind"] = rec.pop("type")  # restore the metric's kind
                metrics[rec["name"]] = rec
            elif kind == "event":
                events.append(rec)
            else:
                raise ValueError(f"{path}: unknown record kind {kind!r}")
    return {"meta": meta, "metrics": metrics, "events": events}


def top_band_share(values: Sequence[float]) -> float:
    """Fraction of all band counts that landed in the top (last) band —
    the paper's norm-bias concentration number."""
    v = np.asarray(values, np.float64)
    total = v.sum()
    return float(v[-1] / total) if total > 0 else 0.0


def render_band_table(
    values: Sequence[float],
    edges: Optional[Sequence[float]] = None,
    *,
    label: str = "band",
    width: int = 40,
) -> str:
    """Render a norm-band eval histogram as an aligned heat table.

    values: per-band counts (band 0 = smallest norms .. last = largest).
    edges:  optional n_bands+1 norm edges for a (lo, hi] range column.
    """
    v = np.asarray(values, np.float64)
    total = v.sum()
    peak = v.max() if v.size else 0.0
    lines = [f"{label:>8}  {'norm range':>17}  {'evals':>12}  share"]
    for i, count in enumerate(v):
        if edges is not None and len(edges) == len(v) + 1:
            rng = f"({edges[i]:7.3f},{edges[i + 1]:7.3f}]"
        else:
            rng = f"{'—':>17}"
        share = count / total if total > 0 else 0.0
        bar = "#" * int(round(width * (count / peak))) if peak > 0 else ""
        lines.append(
            f"{i:>8}  {rng:>17}  {count:>12.0f}  {share:6.1%} {bar}"
        )
    lines.append(
        f"{'total':>8}  {'':>17}  {total:>12.0f}  top-{label} share "
        f"{top_band_share(v):.1%}"
    )
    return "\n".join(lines)


def render_latency_timeline(
    events: List[dict],
    *,
    n_bins: int = 12,
    width: int = 40,
) -> str:
    """Render ``response`` events (fields: t, latency_s) as a binned p50/p99
    timeline.  Timestamps are whatever clock the loop ran on (virtual runs
    render deterministically)."""
    resp = [e for e in events if e.get("event") == "response"]
    if not resp:
        return "(no response events)"
    t = np.array([e["t"] for e in resp])
    lat_ms = np.array([e["latency_s"] for e in resp]) * 1e3
    t0, t1 = t.min(), t.max()
    span = max(t1 - t0, 1e-9)
    bins = np.minimum((n_bins * (t - t0) / span).astype(int), n_bins - 1)
    peak = lat_ms.max()
    lines = [
        f"{'t (s)':>14}  {'n':>5}  {'p50 ms':>8}  {'p99 ms':>8}",
    ]
    for i in range(n_bins):
        sel = lat_ms[bins == i]
        lo = t0 + span * i / n_bins
        hi = t0 + span * (i + 1) / n_bins
        if sel.size == 0:
            lines.append(f"[{lo:5.2f},{hi:5.2f})  {0:>5}  {'—':>8}  {'—':>8}")
            continue
        p50, p99 = np.percentile(sel, [50, 99])
        bar = "#" * int(round(width * (p99 / peak))) if peak > 0 else ""
        lines.append(
            f"[{lo:5.2f},{hi:5.2f})  {sel.size:>5}  {p50:>8.2f}  "
            f"{p99:>8.2f} {bar}"
        )
    lines.append(
        f"{'overall':>14}  {len(lat_ms):>5}  "
        f"{np.percentile(lat_ms, 50):>8.2f}  {np.percentile(lat_ms, 99):>8.2f}"
    )
    return "\n".join(lines)
