"""Observability layer: device-side walk tracing, a host-side metrics
registry, and snapshot/report exporters (docs/ARCHITECTURE.md).

Deliberately importable from everywhere — nothing here imports
``repro.core`` or ``repro.launch``, so core kernels, the build drivers and
the serving loop can all report into it without cycles.
"""
from repro.obs.export import (
    load_jsonl,
    render_band_table,
    render_latency_timeline,
    top_band_share,
    write_metrics,
)
from repro.obs.recall import recall_at_k, recall_curve
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    VectorCounter,
    get_registry,
    set_registry,
)
from repro.obs.trace import (
    TraceContext,
    WalkTrace,
    make_trace_context,
    step_of_column,
    walk_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TraceContext",
    "VectorCounter",
    "WalkTrace",
    "get_registry",
    "load_jsonl",
    "make_trace_context",
    "recall_at_k",
    "recall_curve",
    "render_band_table",
    "render_latency_timeline",
    "set_registry",
    "step_of_column",
    "top_band_share",
    "walk_trace",
    "write_metrics",
]
