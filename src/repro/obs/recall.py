"""Recall / evaluation-count metrics (paper §5).

Moved verbatim from ``repro.core.metrics`` so the quality metrics live next
to the rest of the observability layer (and the old module name is free of
the collision with :mod:`repro.obs.registry`).  ``repro.core.metrics``
remains as a deprecation shim re-exporting these names.
"""
from __future__ import annotations

import numpy as np


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean recall@k over queries.

    pred_ids: [B, k'] (k' >= k allowed; -1 padding ignored)
    true_ids: [B, k]  ground-truth ids
    """
    pred = np.asarray(pred_ids)
    true = np.asarray(true_ids)
    b, k = true.shape
    hit = (pred[:, :, None] == true[:, None, :]) & (true[:, None, :] >= 0)
    per_query = hit.any(axis=1).sum(axis=-1) / k
    return float(per_query.mean())


def recall_curve(results: list, true_ids: np.ndarray) -> list:
    """[(evals_mean, recall)] points for a list of SearchResults at
    increasing search effort — the paper's Fig-8a axis."""
    out = []
    for res in results:
        out.append(
            (
                float(np.mean(np.asarray(res.evals))),
                recall_at_k(np.asarray(res.ids), true_ids),
            )
        )
    return out
