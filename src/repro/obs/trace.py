"""Device-side walk tracing — per-hop traces and norm-bias reductions.

The paper's argument is diagnostic: MIPS walks concentrate their similarity
evaluations on large-norm, high-in-degree hub nodes (Figs 1/4/5).  This
module turns those one-off figure scripts into an always-available runtime
signal: pass a :class:`TraceContext` to ``beam_search`` (or any index
``search``) and the result carries a :class:`WalkTrace` with

  ids / scores / step — the first ``trace_cap`` visited ids per query, their
      walk scores, and a static column->step map (step 0 = seeds, step t>=1 =
      the t-th expansion round) — the raw per-hop signal the ROADMAP's
      learned-routing item needs as training data.
  band_hist — evaluations per norm band (default: deciles of the catalog
      norm distribution), the Fig-5 histogram recomputed per batch.
  hub_evals — evaluations that landed on the precomputed top-in-degree hub
      set (Fig-4's hub concentration).
  steps_to_converge — expansion rounds in which the query scored at least
      one new node (its personal walk length, vs. the batch-max ``steps``).

How it works — and why both step backends get tracing for free: the walk
already appends every scored id to the ``visited`` ring buffer with exact
step structure (columns ``< S`` are the seeds; column ``S + t*M + j`` is
neighbor ``j`` of expansion round ``t``; invalid slots are ``-1``).  The
trace is therefore computed *after* the while_loop, inside the same jit
program, purely from ``visited`` — the loop body is untouched, so
``trace=None`` is trivially bit-identical to an untraced walk (pinned in
tests/test_obs.py), and the reference and pallas backends share one
implementation.  Trace scores are recomputed with the walk's own scorer
(the quantized store scorer under ``storage="int8"``), so they match what
the walk actually ranked by.

All shapes are static functions of ``(trace_cap, n_bands)`` and the walk
geometry: flipping tracing on/off changes the *pytree structure* of one
argument, which jit treats as a different cache entry — one extra compile
per bucket when first enabled, then zero steady-state recompiles (pinned in
tests/test_obs.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp


class WalkTrace(NamedTuple):
    """Per-query walk telemetry (fixed shapes; see module docstring)."""

    ids: jax.Array                # [B, C] int32 first C visited ids (-1 pad)
    scores: jax.Array             # [B, C] fp32 walk scores (-inf at pads)
    step: jax.Array               # [C] int32 static column -> step map
    band_hist: jax.Array          # [B, n_bands] int32 evals per norm band
    hub_evals: jax.Array          # [B] int32 evals on the hub set
    steps_to_converge: jax.Array  # [B] int32 rounds with >=1 new eval


@jax.tree_util.register_pytree_node_class
class TraceContext:
    """Precomputed catalog-side lookup tables the trace reduces against.

    Registered as a pytree so it can cross jit boundaries: the arrays
    (``band_ids``, ``hub_mask``, ``band_edges``) are leaves; the static
    shape parameters ``(trace_cap, n_bands)`` ride in aux_data and become
    part of the jit cache key.  Build one with :func:`make_trace_context`.
    """

    def __init__(self, band_ids, hub_mask, band_edges, *,
                 trace_cap: int, n_bands: int):
        self.band_ids = band_ids      # [N] int32 node -> norm band
        self.hub_mask = hub_mask      # [N] bool  node in top-in-degree set
        self.band_edges = band_edges  # [n_bands + 1] fp32 norm band edges
        self.trace_cap = int(trace_cap)
        self.n_bands = int(n_bands)

    def tree_flatten(self):
        leaves = (self.band_ids, self.hub_mask, self.band_edges)
        return leaves, (self.trace_cap, self.n_bands)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        band_ids, hub_mask, band_edges = leaves
        trace_cap, n_bands = aux
        return cls(band_ids, hub_mask, band_edges,
                   trace_cap=trace_cap, n_bands=n_bands)

    def __repr__(self):
        n = getattr(self.band_ids, "shape", ("?",))[0]
        return (f"TraceContext(n={n}, n_bands={self.n_bands}, "
                f"trace_cap={self.trace_cap})")


def make_trace_context(
    norms,
    adj=None,
    *,
    size: Optional[int] = None,
    trace_cap: int = 128,
    n_bands: int = 10,
    hub_frac: float = 0.01,
) -> TraceContext:
    """Build a :class:`TraceContext` from catalog norms (+ optional adjacency).

    norms:     [N] item norms (N = catalog size, or the mutable capacity —
               pool slots included so upserted nodes stay in range).
    adj:       optional [N, M] adjacency; when given, the hub set is the top
               ``ceil(hub_frac * size)`` nodes by in-degree (the paper's
               Fig-4 axis).  Without it ``hub_evals`` reads as all-zero.
    size:      number of *real* nodes (defaults to N); band edges are fitted
               on ``norms[:size]`` so uninitialized capacity slots don't
               skew the deciles.
    trace_cap: per-query visited-prefix length carried in the trace.
    n_bands:   norm bands (10 = the paper's deciles).

    Host-side, numpy, done once per index — the per-walk cost is two int
    gathers and a one-hot reduce.
    """
    norms = np.asarray(norms, np.float32).reshape(-1)
    n = norms.shape[0]
    size = n if size is None else int(size)
    if not 0 < size <= n:
        raise ValueError(f"size must be in (0, {n}], got {size}")
    if trace_cap <= 0 or n_bands <= 0:
        raise ValueError(
            f"trace_cap and n_bands must be positive, got "
            f"trace_cap={trace_cap} n_bands={n_bands}"
        )
    edges = np.quantile(norms[:size], np.linspace(0.0, 1.0, n_bands + 1))
    edges = edges.astype(np.float32)
    # Interior edges only: band i covers (edges[i], edges[i+1]], clamped to
    # [0, n_bands-1] so out-of-range norms (churned-in items) still land in
    # an end band instead of indexing out of bounds.
    band_ids = np.searchsorted(edges[1:-1], norms, side="left")
    band_ids = np.clip(band_ids, 0, n_bands - 1).astype(np.int32)

    hub_mask = np.zeros(n, bool)
    if adj is not None:
        adj = np.asarray(adj)
        flat = adj[adj >= 0]
        indeg = np.bincount(flat, minlength=n)[:n]
        n_hubs = max(1, int(np.ceil(hub_frac * size)))
        hub_mask[np.argsort(indeg)[::-1][:n_hubs]] = True

    return TraceContext(
        jnp.asarray(band_ids),
        jnp.asarray(hub_mask),
        jnp.asarray(edges),
        trace_cap=trace_cap,
        n_bands=n_bands,
    )


def step_of_column(n_cols: int, *, seeds: int, degree: int) -> np.ndarray:
    """The static visited-column -> walk-step map: columns ``< seeds`` are
    step 0, column ``seeds + t*degree + j`` is step ``t + 1``."""
    cols = np.arange(n_cols)
    return np.where(
        cols < seeds, 0, 1 + (cols - seeds) // max(degree, 1)
    ).astype(np.int32)


def walk_trace(
    ctx: TraceContext,
    visited: jax.Array,
    queries: jax.Array,
    items: jax.Array,
    score_fn,
    *,
    seeds: int,
    degree: int,
) -> WalkTrace:
    """Reduce a finished walk's visited ring buffer into a WalkTrace.

    Runs inside the caller's jit program (pure jnp, static shapes).
    ``score_fn`` must be the scorer the walk itself used so trace scores
    match the walk's ranking (the quantized scorer under int8 storage).
    """
    b, v = visited.shape
    valid = visited >= 0
    safe = jnp.maximum(visited, 0)

    # Per-hop prefix: the first trace_cap visited columns.  The ring buffer
    # is append-only in step order, so a prefix IS the first hops.
    c = min(ctx.trace_cap, v)
    ids = visited[:, :c]
    tr_valid = valid[:, :c]
    scores = jnp.where(
        tr_valid,
        score_fn(queries, items, jnp.maximum(ids, 0)).astype(jnp.float32),
        -jnp.inf,
    )
    step = jnp.asarray(step_of_column(c, seeds=seeds, degree=degree))

    # Always-on reductions over the FULL buffer (not just the traced prefix).
    bands = ctx.band_ids[safe]
    one_hot = jax.nn.one_hot(bands, ctx.n_bands, dtype=jnp.int32)
    band_hist = (one_hot * valid[..., None].astype(jnp.int32)).sum(axis=1)
    hub_evals = (ctx.hub_mask[safe] & valid).sum(axis=-1).astype(jnp.int32)

    n_steps = (v - seeds) // max(degree, 1)
    per_round = valid[:, seeds:seeds + n_steps * degree]
    per_round = per_round.reshape(b, n_steps, degree).any(axis=-1)
    steps_to_converge = per_round.sum(axis=-1).astype(jnp.int32)

    return WalkTrace(
        ids=jnp.where(tr_valid, ids, -1).astype(jnp.int32),
        scores=scores,
        step=step,
        band_hist=band_hist,
        hub_evals=hub_evals,
        steps_to_converge=steps_to_converge,
    )
