"""Preemption-safe, elastic checkpointing.

Layout:  <dir>/step_<N>/
            shard_<proc>.npz      — this process's leaves (full arrays on a
                                    single host; per-host addressable shards
                                    on a multi-host pod)
            MANIFEST.json          — step, leaf names/shapes/dtypes, #procs
         <dir>/LATEST               — committed step pointer

Commit protocol: write into step_<N>.tmp/, fsync, atomic-rename the directory,
then atomically rewrite LATEST.  A checkpoint either exists completely or not
at all; a killed writer leaves only *.tmp debris that restore ignores and the
next save overwrites.

Elasticity: leaves are stored as GLOBAL arrays keyed by pytree path, so a
restore may re-shard onto any device count / mesh shape — restore() takes the
target template (+ optional shardings) and uses jax.device_put per leaf.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_names(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        names.append(jax.tree_util.keystr(path))
    return names


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    proc = jax.process_index()
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    names = _leaf_names(tree)

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    arrays = {}
    for name, leaf in zip(names, leaves):
        arrays[name] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **arrays)

    manifest = {
        "step": step,
        "n_procs": jax.process_count(),
        "leaves": {
            n: {"shape": list(a.shape), "dtype": str(a.dtype)}
            for n, a in arrays.items()
        },
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        step = int(f.read().strip())
    if not os.path.isdir(os.path.join(ckpt_dir, f"step_{step:08d}")):
        return None  # pointer ahead of a crashed commit — treat as absent
    return step


def restore(
    ckpt_dir: str,
    template,
    step: Optional[int] = None,
    shardings=None,
):
    """Restore into the structure of ``template`` (ShapeDtypeStructs or
    arrays).  ``shardings``: optional matching pytree of NamedSharding for
    elastic placement onto the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)

    data: dict = {}
    for fname in sorted(os.listdir(d)):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            with np.load(os.path.join(d, fname)) as z:
                for k in z.files:
                    data[k] = z[k]

    names = _leaf_names(template)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves_t)
    )
    out = []
    for name, tmpl, shd in zip(names, leaves_t, shard_leaves):
        if name not in data:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = data[name].astype(tmpl.dtype)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{name}: ckpt {arr.shape} != template {tmpl.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
