"""Preemption-safe training loop with straggler telemetry.

Contract: ``step_fn(state, batch) -> (state, metrics)`` is a jit-compiled
pure function; ``state`` is a pytree containing params + optimizer state (and
anything else that must survive a restart).  The data-pipeline state is the
step counter (streams are pure functions of step — data/synthetic.py), so a
restore resumes bit-exactly.

Fault model (1000-node posture, documented for the launcher):
  * preemption/crash  — every ``ckpt_every`` steps the full state commits
    atomically (checkpoint.py); a restarted worker re-joins from LATEST.
  * elastic restart   — checkpoints are global arrays; a different device
    count re-shards at restore time via the target shardings.
  * stragglers        — per-step wall time is tracked with an EWMA; steps
    slower than ``straggler_factor``x the EWMA are counted and logged so the
    launcher can decide to replace the worker (on single-host CPU this is
    telemetry only).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint as ckpt


class TrainResult:
    def __init__(self, state, history, straggler_steps):
        self.state = state
        self.history = history
        self.straggler_steps = straggler_steps


def run(
    step_fn: Callable,
    init_state,
    stream,
    *,
    n_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    straggler_factor: float = 3.0,
    state_shardings=None,
    verbose: bool = True,
) -> TrainResult:
    state = init_state
    start_step = 0

    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            template = jax.eval_shape(lambda: init_state)
            state, manifest = ckpt.restore(
                ckpt_dir, template, step=latest, shardings=state_shardings
            )
            start_step = latest
            if verbose:
                print(f"[loop] resumed from step {latest}")

    history = []
    straggler_steps = []
    ewma = None
    for step in range(start_step, n_steps):
        batch = stream.batch_at(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics)
        dt = time.perf_counter() - t0

        if ewma is None:
            ewma = dt
        elif dt > straggler_factor * ewma:
            straggler_steps.append((step, dt, ewma))
            if verbose:
                print(f"[loop] straggler step {step}: {dt:.3f}s vs ewma {ewma:.3f}s")
        ewma = 0.9 * ewma + 0.1 * dt

        history.append(jax.tree.map(float, metrics))
        if verbose and step % log_every == 0:
            print(f"[loop] step {step}: {history[-1]} ({dt*1e3:.1f} ms)")

        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)

    if ckpt_dir is not None and n_steps > start_step:
        ckpt.save(ckpt_dir, n_steps, state)
    return TrainResult(state, history, straggler_steps)
