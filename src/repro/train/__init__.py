from repro.train.optimizer import (
    AdamWState,
    adamw_init,
    adamw_specs,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.train import checkpoint, compress, loop
