"""int8 error-feedback gradient compression (1-bit-Adam-family trick,
adapted to TPU all-reduce).

Protocol per tensor (inside shard_map over the data axes):
  1. c = g + e                      (carry the quantization error forward)
  2. s = pmax(max|c|) / 127         (shared scale — one scalar all-reduce)
  3. q = round(c / s)  in int8      (4x wire compression vs fp32)
  4. r = psum(q) * s / n_shards     (int32 accumulate: n_shards*127 << 2^31)
  5. e' = c - q * s                 (local error feedback)

Compression acts on the ALL-REDUCE WIRE format only; the math converges to
the uncompressed mean as errors are re-fed (validated in tests against the
exact mean within tolerance over repeated steps).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _compress_one(g, e, axes):
    c = g.astype(jnp.float32) + e
    amax = jax.lax.pmax(jnp.max(jnp.abs(c)), axes)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axes)
    n_shards = jax.lax.psum(jnp.ones((), jnp.int32), axes)
    mean = total.astype(jnp.float32) * scale / n_shards.astype(jnp.float32)
    e_new = c - q.astype(jnp.float32) * scale
    return mean, e_new


def compressed_grad_mean(grads, errors, axes):
    """Apply the int8 EF all-reduce to every leaf.  Must be called INSIDE a
    shard_map whose manual axes include ``axes``.  Returns (mean_grads,
    new_errors)."""
    out = jax.tree.map(lambda g, e: _compress_one(g, e, axes), grads, errors)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean, errs


def error_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_allreduce(mesh, axes: Sequence[str]):
    """Standalone compressed all-reduce-mean: x has a leading shard axis of
    size prod(mesh[axes]); e is the matching per-shard error state.
    Returns f(x, e) -> (mean broadcast back per shard, new errors)."""
    axes = tuple(axes)

    def body(x, e):
        m, e2 = _compress_one(x[0], e[0], axes)
        return m[None], e2[None]

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=(P(axes), P(axes)),
        check_vma=False,
    )
