"""AdamW + schedules, implemented directly in JAX (no optax dependency).

Moments are fp32 regardless of param dtype (bf16 params + fp32 moments is the
memory plan that fits grok-1/qwen3 on v5e — DESIGN.md §5); optimizer state
shards exactly like the parameters (ZeRO-style via identical NamedSharding).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jax.Array  # [] int32
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_specs(param_specs) -> AdamWState:
    """Optimizer-state shardings mirror the parameter shardings."""
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, 0.0
    )
    return jnp.sqrt(sq)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip: float = 1.0,
):
    """Returns (new_params, new_state).  ``lr`` may be a scalar array."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int, min_frac=0.1):
    s = step.astype(jnp.float32)
    warm = base_lr * s / max(warmup, 1)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup, warm, cos)
